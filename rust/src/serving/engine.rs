//! The inference engine: worker threads each owning a `Transformer`
//! instance, pulling batches from the shared queue, running
//! prefill → decode per request, and reporting completions.
//!
//! # Request lifecycle
//!
//! Every admitted request reaches **exactly one** terminal outcome —
//! a response, a backpressure shed, a `deadline exceeded` error, or a
//! `cancelled` error — never a hang. Deadlines and cancellation are
//! checked at three points: admission ([`InferenceEngine::submit`]),
//! slot assignment (when a worker seats a queued request), and between
//! decode steps (so an expired or abandoned sequence frees its slot
//! within one lockstep step).
//!
//! # Worker supervision
//!
//! Each batch step runs under `catch_unwind`. A panic is converted into
//! per-slot terminal error responses (no leaked `inflight`, no hung
//! waiters); the request that was mid-prefill when the panic hit is
//! quarantined — re-run once from scratch, then poisoned on a second
//! panic — and the worker rebuilds its `Transformer` (cheap: the plan
//! store is shared) and keeps serving. `panics_total` counts caught
//! panics in the metrics snapshot.
//!
//! # Heartbeat
//!
//! Workers stamp a shared heartbeat at the top of every loop iteration
//! and after every completed step. [`InferenceEngine::heartbeat_age`]
//! is the router's health signal: a worker wedged inside a step (or a
//! stalled host) stops beating, and the router routes around the
//! replica until the heartbeat recovers.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{FairQueue, PushError};
use super::request::{Frame, Request, Response, Timing};
use super::scheduler::{schedule, Policy};
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::model::sampler::Sampler;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::runtime::kv_pool::KvPool;
use crate::runtime::plan_store::PlanStore;
use crate::tune::candidates::TunedBackend;
use crate::tune::profile::TuneProfile;
use crate::util::json::Json;
use crate::util::obs::{LayerProfile, Level, TraceBuilder, TraceRing};
use crate::util::rng::Rng;

/// Deterministic fault injection for the lifecycle test harness.
///
/// Threaded through [`EngineConfig::fault`]; compiled only for tests
/// and the `fault-inject` feature, so release binaries carry no
/// injection branches unless explicitly built with the feature. Step
/// numbers refer to the engine-wide step counter (each lockstep step —
/// or each sequential request — gets a unique, monotonically
/// increasing number), so every trigger fires exactly once.
#[cfg(any(test, feature = "fault-inject"))]
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Panic the worker when the step counter reaches any listed step.
    pub panic_at_steps: Vec<u64>,
    /// Stall the worker (sleep) for `.1` milliseconds when the step
    /// counter reaches `.0` — wedges the heartbeat for that long.
    pub stall_at_step: Option<(u64, u64)>,
    /// Reject every submit as queue-full (admission-control testing).
    pub force_queue_full: bool,
    /// Pretend the KV pool is exhausted just before the listed step:
    /// the pressure checkpoint must evict the youngest live slot with
    /// `KvBudgetExceeded`, exactly as if the real budget ran dry.
    pub exhaust_kv_at_step: Option<u64>,
}

/// Fault checkpoint executed (inside the supervised section) just
/// before a model step.
#[cfg(any(test, feature = "fault-inject"))]
fn fault_before_step(step: u64, cfg: &EngineConfig) {
    if let Some((at, ms)) = cfg.fault.stall_at_step {
        if step == at {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }
    if cfg.fault.panic_at_steps.contains(&step) {
        panic!("fault-inject: panic at step {step}");
    }
}

#[cfg(not(any(test, feature = "fault-inject")))]
#[inline(always)]
fn fault_before_step(_step: u64, _cfg: &EngineConfig) {}

#[cfg(any(test, feature = "fault-inject"))]
fn fault_queue_full(cfg: &EngineConfig) -> bool {
    cfg.fault.force_queue_full
}

#[cfg(not(any(test, feature = "fault-inject")))]
#[inline(always)]
fn fault_queue_full(_cfg: &EngineConfig) -> bool {
    false
}

/// Fault checkpoint consulted by the KV pressure sweep: force one
/// youngest-slot eviction just before the given engine step.
#[cfg(any(test, feature = "fault-inject"))]
fn fault_exhaust_kv(step: u64, cfg: &EngineConfig) -> bool {
    cfg.fault.exhaust_kv_at_step == Some(step)
}

#[cfg(not(any(test, feature = "fault-inject")))]
#[inline(always)]
fn fault_exhaust_kv(_step: u64, _cfg: &EngineConfig) -> bool {
    false
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own `Transformer`).
    pub workers: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Scheduling policy within a batch.
    pub schedule: Policy,
    /// Multiply backend for the model.
    pub backend: Backend,
    /// Blocking parameter (0 → analytic optimum).
    pub k: usize,
    /// Directory of `.rsrz` plan artifacts (the `rsr pack` output).
    /// When set — and the backend is an RSR plan backend — workers load
    /// preprocessed plans from disk instead of running Algorithm 1 at
    /// startup. When `None`, plans are still built only once per
    /// process and shared across workers via the [`PlanStore`].
    pub plan_dir: Option<PathBuf>,
    /// `.rsrt` tuning profile (the `rsr tune` output). When set — RSR++
    /// backend only, like `plan_dir` — every layer materializes with
    /// its measured `(k, backend)` winner instead of the analytic
    /// defaults. The profile must have been tuned on this machine
    /// (fingerprint-checked at startup).
    pub tune_profile: Option<PathBuf>,
    /// Per-request trace timelines: `Some(ms)` turns tracing on and
    /// pins any request slower than `ms` milliseconds (or any request
    /// that did not complete cleanly) into the retained slow-log.
    /// `None` — the default — compiles every trace hook down to a
    /// branch on a `None` option: no locks, no allocation, no extra
    /// `Instant::now()` on the decode path.
    pub trace_slow_ms: Option<u64>,
    /// Per-(layer, backend) execution profiling (`--profile-layers`).
    /// Off by default: every probe site is then a single branch.
    pub profile_layers: bool,
    /// Hard byte budget for all KV pages (`--kv-budget`). `None` — the
    /// default — serves bit-identically to the unbudgeted engine:
    /// pages still allocate lazily, but no reservation can fail and no
    /// eviction ever fires.
    pub kv_budget: Option<u64>,
    /// Positions per KV page (`--kv-page-tokens`).
    pub kv_page_tokens: usize,
    /// Fault-injection plan (tests / `fault-inject` feature only).
    #[cfg(any(test, feature = "fault-inject"))]
    pub fault: FaultPlan,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            schedule: Policy::default(),
            backend: Backend::RsrPlusPlus,
            k: 0,
            plan_dir: None,
            tune_profile: None,
            trace_slow_ms: None,
            profile_layers: false,
            kv_budget: None,
            kv_page_tokens: KvPool::DEFAULT_PAGE_TOKENS,
            #[cfg(any(test, feature = "fault-inject"))]
            fault: FaultPlan::default(),
        }
    }
}

/// A running engine: submit requests, receive responses.
///
/// The response receiver is Mutex-wrapped so the engine is `Sync`; in
/// multi-consumer settings (the TCP server) a single dispatcher thread
/// should own consumption (see `server::ResponseHub`).
pub struct InferenceEngine {
    queue: Arc<FairQueue>,
    metrics: Arc<Metrics>,
    responses: std::sync::Mutex<mpsc::Receiver<Frame>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    /// Drain mode: in-flight and queued work completes, new submissions
    /// are refused with [`Error::Draining`]. Never reset — draining is
    /// the beginning of the end of the process.
    draining: Arc<AtomicBool>,
    /// Engine start instant — the heartbeat's epoch and the trace
    /// timestamp base.
    epoch: Instant,
    /// Milliseconds since `epoch` of the most recent worker heartbeat.
    heartbeat_ms: Arc<AtomicU64>,
    /// Recent + slow-pinned request timelines (`--trace-slow-ms`);
    /// `None` when tracing is off.
    trace: Option<Arc<TraceRing>>,
    /// Per-(layer, backend) execution aggregates (`--profile-layers`);
    /// `None` when profiling is off.
    layer_profile: Option<Arc<LayerProfile>>,
    /// Decode slots currently seated across all workers (the
    /// `rsr_live_slots` gauge).
    live_slots: Arc<AtomicUsize>,
    /// The engine-wide KV page pool (all layers × slots × workers draw
    /// from it; `--kv-budget` caps it, unset leaves it unbounded).
    kv_pool: Arc<KvPool>,
    /// Decoder depth — every cached position costs one page slot per
    /// layer, so admission math multiplies by this.
    n_layers: usize,
    cfg: EngineConfig,
}

impl InferenceEngine {
    /// Start workers.
    ///
    /// On the RSR++ backend (the default), model preparation goes
    /// through a process-shared [`PlanStore`]: each weight matrix is
    /// preprocessed (paper Algorithm 1) — or loaded from a packed
    /// `.rsrz` artifact when [`EngineConfig::plan_dir`] is set — **at
    /// most once**, and every worker thread shares the resulting index,
    /// holding only per-thread scratch. Other backends keep the
    /// original prepare-per-worker path.
    pub fn start(weights: Arc<ModelWeights>, cfg: EngineConfig) -> Result<Self> {
        let store = Self::build_plan_store(&weights, &cfg)?;
        Self::spawn(weights, cfg, store)
    }

    /// Resolve the `(plan_dir, backend)` policy into the optional
    /// shared store [`start`](Self::start) uses. The single source of
    /// truth for that policy: `rsr serve` calls it once and hands the
    /// same store to every replica via
    /// [`start_with_store`](Self::start_with_store).
    pub fn build_plan_store(
        weights: &Arc<ModelWeights>,
        cfg: &EngineConfig,
    ) -> Result<Option<Arc<PlanStore>>> {
        // Load + host-verify the tuning profile first: a foreign or
        // corrupt .rsrt must fail startup before any preprocessing is
        // paid for.
        let profile = match &cfg.tune_profile {
            None => None,
            Some(path) => {
                if cfg.backend != Backend::RsrPlusPlus {
                    return Err(Error::Config(format!(
                        "tuning profiles drive the rsr++ plan path; backend {} \
                         cannot use --profile",
                        cfg.backend.name()
                    )));
                }
                let p = TuneProfile::load(path).map_err(|e| {
                    Error::Artifact(format!("loading {}: {e}", path.display()))
                })?;
                p.verify_host()?;
                println!(
                    "loaded tuning profile {} ({} layers, machine {})",
                    path.display(),
                    p.len(),
                    p.fingerprint.describe()
                );
                // The tuner measures the parallel backend on an
                // uncontended pool; many engine workers contend the
                // checkout (losers fall back to serial), so the tuned
                // ranking may not hold — say so rather than silently
                // serving a loser.
                let parallel_layers = p
                    .layers
                    .iter()
                    .filter(|l| l.winner().backend == TunedBackend::Parallel)
                    .count();
                if parallel_layers > 0 && cfg.workers > 1 {
                    crate::log!(
                        Level::Warn,
                        "profile selects the parallel backend for \
                         {parallel_layers} layer(s), but it was measured without \
                         pool contention; with workers={} the shared pool will \
                         contend and rsr++ may serve faster — consider --workers 1 \
                         or re-tuning under load",
                        cfg.workers
                    );
                }
                // The batched candidate is microbenched at one
                // synthetic batch size (recorded in the .rsrt header);
                // an engine decoding at a materially different
                // occupancy may see a different ranking.
                let batched_layers = p
                    .layers
                    .iter()
                    .filter(|l| l.winner().backend == TunedBackend::Batched)
                    .count();
                let tuned_b = (p.bench_batch as usize).max(1);
                let slots = cfg.batch.max_slots.max(1);
                if batched_layers > 0 && slots.max(tuned_b) >= 2 * slots.min(tuned_b) {
                    crate::log!(
                        Level::Warn,
                        "profile's batched winner ({batched_layers} \
                         layer(s)) was measured at batch {tuned_b}, but the engine \
                         decodes with max_slots {slots} — the measured ranking may \
                         not hold at this occupancy; serve --max-slots {tuned_b} to \
                         match the measurement, or treat batched winners as \
                         approximate"
                    );
                }
                Some(p)
            }
        };
        let with_profile = |store: PlanStore| -> Result<PlanStore> {
            match profile {
                Some(p) => store.with_profile(p),
                None => Ok(store),
            }
        };
        match (&cfg.plan_dir, cfg.backend) {
            (Some(dir), Backend::RsrPlusPlus) => {
                let store = with_profile(PlanStore::open(dir)?)?;
                // Resolve every layer now: a missing or corrupt
                // artifact fails engine startup, not the first request.
                store.preload(&weights.matrix_names())?;
                // One whole-store weights check here, so worker builds
                // skip their per-layer fingerprint recomputation.
                store.verify_fingerprints(weights)?;
                Ok(Some(Arc::new(store)))
            }
            (Some(_), other) => Err(Error::Config(format!(
                "plan artifacts execute via rsr++; backend {} cannot use --plans",
                other.name()
            ))),
            (None, Backend::RsrPlusPlus) => {
                let store =
                    with_profile(PlanStore::for_model(Arc::clone(weights), cfg.k))?;
                // Preprocess every layer HERE, before workers spawn:
                // lazily-racing worker threads would otherwise all miss
                // the cold cache together and run Algorithm 1 in
                // parallel duplicate — the exact W× cost this store
                // exists to eliminate.
                store.preload(&weights.matrix_names())?;
                Ok(Some(Arc::new(store)))
            }
            (None, _) => Ok(None),
        }
    }

    /// Start workers against an externally owned [`PlanStore`] — the
    /// multi-replica path: `rsr serve --replicas N` builds one store
    /// and passes the same `Arc` to every replica, so the whole process
    /// holds each layer's index exactly once. The store's plans execute
    /// via RSR++; `cfg.backend`/`cfg.k`/`cfg.plan_dir` are ignored on
    /// this path.
    pub fn start_with_store(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Arc<PlanStore>,
    ) -> Result<Self> {
        Self::spawn(weights, cfg, Some(store))
    }

    fn spawn(
        weights: Arc<ModelWeights>,
        cfg: EngineConfig,
        store: Option<Arc<PlanStore>>,
    ) -> Result<Self> {
        let queue = Arc::new(FairQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Frame>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let epoch = Instant::now();
        let heartbeat_ms = Arc::new(AtomicU64::new(0));
        let step_counter = Arc::new(AtomicU64::new(0));
        let trace = cfg
            .trace_slow_ms
            .map(|ms| Arc::new(TraceRing::with_threshold(Duration::from_millis(ms))));
        let layer_profile = cfg.profile_layers.then(|| Arc::new(LayerProfile::new()));
        let live_slots = Arc::new(AtomicUsize::new(0));
        // One pool for the whole engine: every layer of every worker's
        // model draws pages from it, so `--kv-budget` is a process
        // ceiling, not a per-worker one.
        let kv_dim = weights.config.n_kv_heads * weights.config.head_dim();
        let n_layers = weights.config.n_layers;
        let page_tokens = cfg.kv_page_tokens.max(1);
        let kv_pool = match cfg.kv_budget {
            Some(bytes) => Arc::new(KvPool::bounded(page_tokens, kv_dim, bytes)?),
            None => Arc::new(KvPool::unbounded(page_tokens)),
        };

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for wid in 0..cfg.workers.max(1) {
            let ctx = WorkerCtx {
                queue: Arc::clone(&queue),
                metrics: Arc::clone(&metrics),
                tx: tx.clone(),
                inflight: Arc::clone(&inflight),
                shutdown: Arc::clone(&shutdown),
                step_counter: Arc::clone(&step_counter),
                epoch,
                heartbeat_ms: Arc::clone(&heartbeat_ms),
                trace: trace.clone(),
                live_slots: Arc::clone(&live_slots),
                kv_pool: Arc::clone(&kv_pool),
                n_layers,
                cfg: cfg.clone(),
            };
            let weights = Arc::clone(&weights);
            let store = store.clone();
            let profile = layer_profile.clone();
            let pool = Arc::clone(&kv_pool);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rsr-worker-{wid}"))
                    .spawn(move || {
                        // Fixed weights — preprocessing amortizes (the
                        // paper's core observation): shared plans from
                        // the store, or per-worker prepare otherwise.
                        // The same builder rebuilds the model after a
                        // supervised panic (the "respawn" of the
                        // supervision policy); probe dedupe keeps the
                        // rebuilt model accumulating into the same
                        // per-layer aggregates.
                        let rebuild = || -> Result<Transformer> {
                            let mut m = match &store {
                                Some(s) => Transformer::from_plan_store_pooled(
                                    &weights,
                                    s,
                                    Arc::clone(&pool),
                                )?,
                                None => Transformer::from_weights_pooled(
                                    &weights,
                                    ctx.cfg.backend,
                                    ctx.cfg.k,
                                    Arc::clone(&pool),
                                )?,
                            };
                            if let Some(p) = &profile {
                                m.attach_layer_probes(p);
                            }
                            Ok(m)
                        };
                        let model = match rebuild() {
                            Ok(m) => m,
                            Err(e) => {
                                crate::log!(
                                    Level::Error,
                                    "model build failed worker={wid} err={e}"
                                );
                                return;
                            }
                        };
                        worker_loop(model, &ctx, &rebuild);
                    })
                    .map_err(|e| Error::Serving(e.to_string()))?,
            );
        }
        Ok(Self {
            queue,
            metrics,
            responses: std::sync::Mutex::new(rx),
            workers,
            inflight,
            shutdown,
            draining,
            epoch,
            heartbeat_ms,
            trace,
            layer_profile,
            live_slots,
            kv_pool,
            n_layers,
            cfg,
        })
    }

    /// Submit a request; fails fast under backpressure, and sheds
    /// already-dead work (expired deadline / cancelled) before it ever
    /// occupies queue capacity.
    pub fn submit(&self, request: Request) -> Result<()> {
        // Drain refusals — like queue-full sheds — stay un-admitted:
        // the engine never took responsibility for the work, so
        // conservation accounts them under `rejected`.
        if self.is_draining() {
            self.metrics.record_admission(false);
            return Err(Error::Draining("engine is draining — not accepting work".into()));
        }
        if fault_queue_full(&self.cfg) {
            self.metrics.record_admission(false);
            return Err(Error::QueueFull("retry later".into()));
        }
        // Pre-admission sheds reach a terminal outcome, so they count
        // as admitted-with-immediate-terminal — `admitted` bumps BEFORE
        // the terminal counter, keeping the snapshot's conservation
        // residual (`inflight`) non-negative. Queue-full rejections
        // stay un-admitted: the engine never took responsibility.
        if request.cancel.is_cancelled() {
            self.metrics.record_admission(true);
            self.metrics.record_cancelled(request.arrival.elapsed());
            self.trace_shed(&request, "cancelled");
            return Err(Error::Cancelled("request cancelled before admission".into()));
        }
        if request.deadline_expired() {
            self.metrics.record_admission(true);
            self.metrics.record_deadline_exceeded(request.arrival.elapsed());
            self.trace_shed(&request, "deadline_exceeded");
            return Err(Error::DeadlineExceeded(
                "deadline expired before admission".into(),
            ));
        }
        // KV admission checkpoint: a prompt whose pages could not fit
        // even an EMPTY pool can never be seated — shed it now with the
        // named budget error instead of letting it starve in the queue.
        // Transient pressure (pages held by in-flight sequences) is
        // NOT checked here; the seating reservation handles it.
        if self.kv_pool.is_bounded() {
            let needed = self.n_layers * self.kv_pool.pages_for(request.prompt.len());
            if needed > self.kv_pool.total_pages() {
                self.kv_pool.record_reservation_failed();
                self.metrics.record_admission(true);
                self.metrics.record_kv_budget_exceeded(request.arrival.elapsed());
                self.trace_shed(&request, "kv_budget_exceeded");
                return Err(Error::KvBudgetExceeded(format!(
                    "prompt needs {needed} KV pages but the budget holds {}",
                    self.kv_pool.total_pages()
                )));
            }
        }
        let res = self.queue.try_push(request);
        self.metrics.record_admission(res.is_ok());
        match res {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(PushError::Full) => Err(Error::QueueFull("retry later".into())),
            Err(PushError::Closed) => Err(Error::Unavailable("engine shut down".into())),
        }
    }

    /// Receive the next **terminal** response (blocking with timeout),
    /// skipping any interleaved streaming token frames. Single-consumer:
    /// concurrent callers serialize on an internal lock and may steal
    /// each other's responses — multi-connection fronts must use one
    /// dispatcher (see `server::ResponseHub`).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        let deadline = Instant::now() + timeout;
        let rx = self.responses.lock().unwrap();
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(Frame::Done(r)) => return Some(r),
                Ok(Frame::Token { .. }) => continue,
                Err(_) => return None,
            }
        }
    }

    /// Receive the next frame — token or terminal — from any request.
    /// Same single-consumer contract as [`recv_timeout`](Self::recv_timeout).
    pub fn recv_frame_timeout(&self, timeout: Duration) -> Option<Frame> {
        self.responses.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Flip the engine into drain mode: queued and in-flight work runs
    /// to completion, every new [`submit`](Self::submit) is refused
    /// with [`Error::Draining`]. Idempotent; never reversed.
    pub fn set_draining(&self) {
        self.draining.store(true, Ordering::Relaxed);
    }

    /// True once [`set_draining`](Self::set_draining) has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// True when the engine is draining and holds no work — the
    /// server's exit condition.
    pub fn drained(&self) -> bool {
        self.is_draining() && self.load() == 0
    }

    /// Requests admitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Queue depth + inflight, the router's load signal.
    pub fn load(&self) -> usize {
        self.queue.len() + self.inflight()
    }

    /// Time since the last worker heartbeat (top of a worker loop or a
    /// completed step). Idle workers beat every ≤ 50 ms, so a healthy
    /// replica's age stays well under 100 ms plus its longest single
    /// step; a worker wedged *inside* a step stops beating. The
    /// router's staleness threshold must exceed the model's worst-case
    /// step time.
    pub fn heartbeat_age(&self) -> Duration {
        let now_ms = self.epoch.elapsed().as_millis() as u64;
        let last = self.heartbeat_ms.load(Ordering::Relaxed);
        Duration::from_millis(now_ms.saturating_sub(last))
    }

    /// Worker panics caught by supervision since startup.
    pub fn panics_total(&self) -> u64 {
        self.metrics.panics.load(Ordering::Relaxed)
    }

    /// Metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Requests waiting in the bounded queue (the `rsr_queue_depth`
    /// gauge; `load()` adds inflight for routing).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Decode slots currently seated across this engine's workers.
    pub fn live_slots(&self) -> usize {
        self.live_slots.load(Ordering::Relaxed)
    }

    /// The engine-wide KV page pool (gauges, tests, `rsr status`).
    pub fn kv_pool(&self) -> &Arc<KvPool> {
        &self.kv_pool
    }

    /// Time since the engine started.
    pub fn uptime(&self) -> Duration {
        self.epoch.elapsed()
    }

    /// Dump the trace ring (`trace` wire command); `None` when tracing
    /// is off (`trace_slow_ms` unset).
    pub fn trace_snapshot(&self) -> Option<Json> {
        self.trace.as_ref().map(|t| t.snapshot())
    }

    /// The metrics snapshot, extended with the KV pool gauges and —
    /// when `--profile-layers` is on — the per-layer execution profile
    /// (each row's share is attributed against `decode_busy_ns`).
    pub fn snapshot(&self) -> Json {
        let snap = self.metrics.snapshot();
        match snap {
            Json::Obj(mut map) => {
                // Pool gauges: `kv_pages_total` reads 0 on an
                // unbudgeted pool (no ceiling), so dashboards can tell
                // "no budget" from "budget of N".
                let total =
                    if self.kv_pool.is_bounded() { self.kv_pool.total_pages() } else { 0 };
                map.insert("draining".into(), Json::Bool(self.is_draining()));
                map.insert("kv_pages_total".into(), Json::num(total as f64));
                map.insert(
                    "kv_pages_in_use".into(),
                    Json::num(self.kv_pool.pages_in_use() as f64),
                );
                map.insert(
                    "kv_pages_peak".into(),
                    Json::num(self.kv_pool.peak_pages_in_use() as f64),
                );
                map.insert(
                    "kv_reservations_failed_total".into(),
                    Json::num(self.kv_pool.reservations_failed() as f64),
                );
                map.insert(
                    "kv_evictions_total".into(),
                    Json::num(self.kv_pool.evictions() as f64),
                );
                if let Some(profile) = &self.layer_profile {
                    let busy = self.metrics.decode_busy_ns.load(Ordering::Relaxed);
                    map.insert("layers".into(), profile.snapshot(busy));
                }
                Json::Obj(map)
            }
            other => other,
        }
    }

    /// Minimal admitted→terminal timeline for a request shed before it
    /// ever reached a worker (tracing on only).
    fn trace_shed(&self, request: &Request, outcome: &'static str) {
        if let Some(ring) = &self.trace {
            let b = TraceBuilder::new(request.id, us_since(self.epoch, request.arrival));
            ring.record(b.finish(us_since(self.epoch, Instant::now()), outcome));
        }
    }

    /// Stop accepting work, drain, and join workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Everything a worker thread shares with the engine: queue, metrics,
/// response channel, lifecycle bookkeeping, heartbeat, and config.
struct WorkerCtx {
    queue: Arc<FairQueue>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Frame>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    /// Engine-wide lockstep step counter (fault-injection reference
    /// frame; also unique-numbers every supervised section).
    step_counter: Arc<AtomicU64>,
    epoch: Instant,
    heartbeat_ms: Arc<AtomicU64>,
    /// Trace ring (`--trace-slow-ms`); `None` = tracing off, and every
    /// trace hook reduces to one branch.
    trace: Option<Arc<TraceRing>>,
    /// Seated-slot gauge, +1 at seat / −1 at retire.
    live_slots: Arc<AtomicUsize>,
    /// The engine-wide KV page pool (reservation + pressure sweeps).
    kv_pool: Arc<KvPool>,
    /// Decoder depth: one page grant per layer per `page_tokens`
    /// cached positions.
    n_layers: usize,
    cfg: EngineConfig,
}

impl WorkerCtx {
    /// Stamp the shared heartbeat. `fetch_max` so a slow worker never
    /// rolls the replica's freshness backwards.
    fn beat(&self) {
        self.heartbeat_ms
            .fetch_max(self.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
    }

    /// Current trace timestamp, `None` when tracing is off — so the
    /// hot loop takes exactly one `Instant::now()` per step when
    /// enabled and zero when not.
    fn trace_now_us(&self) -> Option<u64> {
        self.trace.as_ref().map(|_| us_since(self.epoch, Instant::now()))
    }
}

/// Microseconds from `epoch` to `t` (saturating: a request stamped
/// before the engine's epoch — impossible in practice — reads 0).
fn us_since(epoch: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(epoch).as_micros() as u64
}

/// Why a request is being retired — the terminal-outcome taxonomy.
enum Retire {
    /// Completed normally.
    Done,
    /// Failed with an engine/model error.
    Failed(String),
    /// Deadline expired (queued past deadline, or retired
    /// mid-generation).
    Deadline,
    /// Client cancelled (disconnect observed by the server).
    Cancelled,
    /// KV page budget could not cover the request: seating reservation
    /// refused, or evicted mid-decode (youngest-first) under page
    /// exhaustion.
    KvBudget(String),
}

impl Retire {
    /// The error string carried by the terminal response (`None` for
    /// success). Deadline/cancel/budget messages are stable prefixes
    /// that tests and clients can match on.
    fn error_message(&self) -> Option<String> {
        match self {
            Retire::Done => None,
            Retire::Failed(m) => Some(m.clone()),
            Retire::Deadline => Some("deadline exceeded".into()),
            Retire::Cancelled => Some("cancelled by client".into()),
            Retire::KvBudget(m) => Some(format!("kv budget exceeded: {m}")),
        }
    }

    /// Outcome label — the same vocabulary as
    /// [`Metrics::OUTCOMES`](super::metrics::OUTCOMES) and the trace
    /// ring's terminal events.
    fn label(&self) -> &'static str {
        match self {
            Retire::Done => "completed",
            Retire::Failed(_) => "failed",
            Retire::Deadline => "deadline_exceeded",
            Retire::Cancelled => "cancelled",
            Retire::KvBudget(_) => "kv_budget_exceeded",
        }
    }

    /// Stable wire code for the terminal error response (same table as
    /// [`Error::code`]; `Failed` is the catch-all `internal`).
    fn code(&self) -> &'static str {
        match self {
            Retire::Done | Retire::Failed(_) => "internal",
            Retire::Deadline => "deadline_exceeded",
            Retire::Cancelled => "cancelled",
            Retire::KvBudget(_) => "kv_budget_exceeded",
        }
    }
}

/// Map a model-step error to its retirement class: a refused KV page
/// grant is the named budget outcome, anything else is a failure.
fn retire_for_model_error(e: &Error, phase: &str) -> Retire {
    match e {
        Error::KvBudgetExceeded(m) => Retire::KvBudget(format!("{phase}: {m}")),
        other => Retire::Failed(format!("{phase}: {other}")),
    }
}

/// Lifecycle preflight shared by the slot-assignment checkpoints:
/// cancellation dominates (an abandoned request's deadline no longer
/// matters to anyone).
fn preflight(request: &Request) -> Option<Retire> {
    if request.cancel.is_cancelled() {
        return Some(Retire::Cancelled);
    }
    if request.deadline_expired() {
        return Some(Retire::Deadline);
    }
    None
}

/// Account one terminal outcome and deliver the response. Every path
/// — success AND failure — records a `total` latency observation
/// (outcome-labelled in the snapshot), so shed and failed work is
/// never invisible in the histograms. Returns `false` when the
/// response receiver is gone (worker exits).
fn account_and_send(
    ctx: &WorkerCtx,
    response: Response,
    outcome: &Retire,
    prompt_tokens: usize,
    arrival: Instant,
) -> bool {
    match outcome {
        Retire::Done => {
            ctx.metrics.record(&response.timing, response.tokens.len(), prompt_tokens)
        }
        Retire::Failed(_) => ctx.metrics.record_failure(arrival.elapsed()),
        Retire::Deadline => ctx.metrics.record_deadline_exceeded(arrival.elapsed()),
        Retire::Cancelled => ctx.metrics.record_cancelled(arrival.elapsed()),
        Retire::KvBudget(_) => ctx.metrics.record_kv_budget_exceeded(arrival.elapsed()),
    }
    ctx.inflight.fetch_sub(1, Ordering::Relaxed);
    ctx.tx.send(Frame::Done(response)).is_ok()
}

/// Terminal outcome for a request that never got (or lost) a slot.
/// Traces as a minimal admitted→terminal timeline (it was never
/// seated).
fn respond_terminal(ctx: &WorkerCtx, request: &Request, outcome: Retire) -> bool {
    if let Some(ring) = &ctx.trace {
        let b = TraceBuilder::new(request.id, us_since(ctx.epoch, request.arrival));
        ring.record(
            b.finish(us_since(ctx.epoch, Instant::now()), outcome.label()),
        );
    }
    let msg = outcome.error_message().unwrap_or_else(|| "retired".into());
    account_and_send(
        ctx,
        Response::err_coded(request.id, msg, outcome.code()),
        &outcome,
        request.prompt.len(),
        request.arrival,
    )
}

/// Render a caught panic payload.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

fn worker_loop(
    model: Transformer,
    ctx: &WorkerCtx,
    rebuild: &dyn Fn() -> Result<Transformer>,
) {
    // `max_slots == 1` with `prefill_chunk == 1` degrades to the
    // strictly sequential loop — the exact pre-batching code path, bit
    // for bit. Anything larger runs continuous batching: a slot map
    // stepped in lockstep, finished sequences retiring and queued
    // requests joining mid-flight. A single slot with a chunk > 1
    // still takes the continuous loop: chunked prefill pays off even
    // with no batchmates (that is the time-to-first-token case).
    if ctx.cfg.batch.max_slots <= 1 && ctx.cfg.batch.prefill_chunk <= 1 {
        sequential_loop(model, ctx, rebuild);
    } else {
        continuous_loop(model, ctx, rebuild);
    }
}

fn sequential_loop(
    mut model: Transformer,
    ctx: &WorkerCtx,
    rebuild: &dyn Fn() -> Result<Transformer>,
) {
    let batcher = Batcher::new(Arc::clone(&ctx.queue), ctx.cfg.batch);
    let mut rng = Rng::new(0xC0FFEE);
    loop {
        ctx.beat();
        if ctx.shutdown.load(Ordering::Relaxed) && ctx.queue.is_empty() {
            break;
        }
        let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
            if ctx.queue.is_closed() && ctx.queue.is_empty() {
                break;
            }
            continue;
        };
        for mut request in schedule(batch.requests, ctx.cfg.schedule) {
            // Supervision retry loop: at most two attempts (quarantine
            // policy — one retry, then poisoned).
            loop {
                ctx.beat();
                // Slot-assignment lifecycle checkpoint.
                if let Some(outcome) = preflight(&request) {
                    if !respond_terminal(ctx, &request, outcome) {
                        return;
                    }
                    break;
                }
                let step_no = ctx.step_counter.fetch_add(1, Ordering::Relaxed) + 1;
                // Sequential traces are coarse (admitted → seated →
                // terminal): `run_request` owns the whole lifetime, so
                // per-step events would mean threading the builder
                // through the hot token loop for the degraded path.
                let trace = ctx.trace.as_ref().map(|_| {
                    let mut b = TraceBuilder::new(
                        request.id,
                        us_since(ctx.epoch, request.arrival),
                    );
                    b.seated(us_since(ctx.epoch, Instant::now()));
                    b
                });
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    fault_before_step(step_no, &ctx.cfg);
                    let out = run_request(&mut model, &request, &mut rng, ctx);
                    // Eager page release: an idle sequential worker
                    // holds zero KV pages between requests.
                    model.reset();
                    out
                }));
                match run {
                    Ok((response, outcome)) => {
                        if let (Some(ring), Some(b)) = (&ctx.trace, trace) {
                            ring.record(b.finish(
                                us_since(ctx.epoch, Instant::now()),
                                outcome.label(),
                            ));
                        }
                        if !account_and_send(
                            ctx,
                            response,
                            &outcome,
                            request.prompt.len(),
                            request.arrival,
                        ) {
                            return;
                        }
                        break;
                    }
                    Err(payload) => {
                        ctx.metrics.record_panic();
                        let msg = panic_message(payload);
                        crate::log!(
                            Level::Warn,
                            "caught panic serving request — rebuilding model \
                             request={} err={msg}",
                            request.id
                        );
                        match rebuild() {
                            Ok(m) => model = m,
                            Err(e) => {
                                let _ = respond_terminal(
                                    ctx,
                                    &request,
                                    Retire::Failed(format!(
                                        "worker rebuild failed after panic: {e}"
                                    )),
                                );
                                crate::log!(Level::Error, "model rebuild failed err={e}");
                                return;
                            }
                        }
                        // A streaming request may already have shipped
                        // token frames — those cannot be unsent, so a
                        // clean re-run (which would re-emit them) is
                        // off the table: it fails terminally instead of
                        // taking the quarantine retry.
                        if request.attempts == 0 && !request.stream {
                            request.attempts = 1;
                            continue; // quarantine retry
                        }
                        let msg = if request.attempts == 0 {
                            format!("worker panicked mid-stream ({msg})")
                        } else {
                            format!("poisoned: request panicked the worker twice ({msg})")
                        };
                        if !respond_terminal(ctx, &request, Retire::Failed(msg)) {
                            return;
                        }
                        break;
                    }
                }
            }
        }
    }
}

/// One live sequence in the continuous-batching slot map.
struct SlotState {
    request: Request,
    /// Next token to feed while decoding (the last sampled token).
    /// While prefilling, the step assembly reads the chunk straight
    /// from `request.prompt[prompt_pos..]` instead.
    next_input: u32,
    /// Prompt tokens consumed so far; `== prompt.len()` once decoding.
    prompt_pos: usize,
    /// Generated tokens.
    tokens: Vec<u32>,
    picked_up: Instant,
    /// Worker-local seating order — the KV pressure sweep evicts the
    /// slot with the **highest** value (the youngest: least work lost,
    /// and the oldest sequences — closest to finishing — keep their
    /// pages).
    seated_seq: u64,
    /// Set by the step that consumes the final prompt token.
    prefill_done: Option<Instant>,
    /// Per-request timeline under `--trace-slow-ms`; `None` when
    /// tracing is off (the builder is slot-local, so recording an
    /// event is a Vec push — no lock until the terminal outcome).
    trace: Option<TraceBuilder>,
}

/// Retire one sequence: build its response, account it, and send it.
/// Returns `false` when the response receiver is gone (worker exits).
fn finish_slot(mut slot: SlotState, outcome: Retire, ctx: &WorkerCtx) -> bool {
    let now = Instant::now();
    ctx.live_slots.fetch_sub(1, Ordering::Relaxed);
    if let (Some(ring), Some(b)) = (&ctx.trace, slot.trace.take()) {
        ring.record(b.finish(us_since(ctx.epoch, now), outcome.label()));
    }
    let arrival = slot.request.arrival;
    let prompt_tokens = slot.request.prompt.len();
    let response = match outcome.error_message() {
        Some(msg) => Response::err_coded(slot.request.id, msg, outcome.code()),
        None => {
            let prefill_end = slot.prefill_done.unwrap_or(now);
            let timing = Timing {
                queue: slot.picked_up.duration_since(slot.request.arrival),
                prefill: prefill_end.duration_since(slot.picked_up),
                decode: now.duration_since(prefill_end),
            };
            Response::ok(slot.request.id, slot.tokens, timing)
        }
    };
    account_and_send(ctx, response, &outcome, prompt_tokens, arrival)
}

/// Supervision: convert a caught step panic into per-slot terminal
/// outcomes. The request that was mid-prefill when the panic hit is
/// quarantined — pushed onto `carryover` for one clean re-run (fresh
/// slot, fresh KV) — unless it already spent its retry, in which case
/// it is poisoned. Decode-phase slots fail terminally (their partial
/// output died with the model state). Returns `false` when the
/// response receiver is gone.
fn supervise_panic(
    payload: Box<dyn std::any::Any + Send>,
    slots: &mut [Option<SlotState>],
    step_slots: &[usize],
    carryover: &mut Vec<Request>,
    ctx: &WorkerCtx,
) -> bool {
    ctx.metrics.record_panic();
    let msg = panic_message(payload);
    crate::log!(
        Level::Warn,
        "caught panic during lockstep step — rebuilding model err={msg}"
    );
    for &i in step_slots {
        let mut st = slots[i].take().expect("was in the step");
        let mid_prefill = st.prompt_pos < st.request.prompt.len();
        if mid_prefill && st.request.attempts == 0 {
            // Quarantine frees the slot without going through
            // `finish_slot` (no terminal outcome yet): the gauge drops
            // here and bumps again when the retry re-seats. The
            // first-attempt trace dies with the slot — the retry
            // starts a fresh timeline.
            ctx.live_slots.fetch_sub(1, Ordering::Relaxed);
            st.request.attempts = 1;
            carryover.push(st.request);
        } else if mid_prefill {
            if !finish_slot(
                st,
                Retire::Failed(format!(
                    "poisoned: request panicked the worker twice ({msg})"
                )),
                ctx,
            ) {
                return false;
            }
        } else if !finish_slot(
            st,
            Retire::Failed(format!("worker panicked mid-generation ({msg})")),
            ctx,
        ) {
            return false;
        }
    }
    // Defensive sweep: every live slot joins every step today, but if
    // that invariant ever changes, a leftover slot's KV still dies with
    // the rebuilt model — fail it loudly rather than decoding garbage.
    for s in slots.iter_mut() {
        if let Some(st) = s.take() {
            if !finish_slot(st, Retire::Failed("worker restarted after a panic".into()), ctx)
            {
                return false;
            }
        }
    }
    true
}

/// The continuous-batching worker: a slot map of up to
/// `cfg.batch.max_slots` sequences stepped in lockstep through
/// [`Transformer::forward_chunk`]. Each step feeds every decoding slot
/// its last sampled token, and every **prefilling** slot a chunk of up
/// to `cfg.batch.prefill_chunk` unconsumed prompt tokens stacked along
/// the batch dimension — so a prompt is consumed as a matrix–matrix
/// workload (one shared-index read per layer per chunk) instead of one
/// decode-rate step per token, which is where time-to-first-token is
/// won. Finished sequences retire their slot; queued requests are
/// admitted into free slots between steps without ever stalling the
/// live ones ([`Batcher::poll`]).
///
/// **Per-step chunk budget:** the total prompt rows one step stacks is
/// capped at `max(prefill_chunk, prefilling slots)` — the fair share
/// `prefill_chunk / prefilling` per slot, floored at one token so
/// every slot still advances each step (more prefilling slots than
/// budget degrades each to one-token prefill, the pre-chunk baseline).
/// One long prompt inflates a step by at most `prefill_chunk − 1` rows
/// and can never starve decoding batchmates of their once-per-step
/// token.
///
/// Per-sequence results are independent of batchmates and chunking is
/// bit-identical to one-token prefill (see
/// [`Transformer::forward_chunk`]), so joins, retirements and chunk
/// boundaries never perturb the tokens of in-flight sequences.
///
/// **Lifecycle:** between steps every live slot is checked for
/// cancellation and deadline expiry (retired with the matching
/// terminal error), each step runs under `catch_unwind` (see
/// [`supervise_panic`]), and the worker stamps the replica heartbeat
/// at the top of every iteration.
fn continuous_loop(
    mut model: Transformer,
    ctx: &WorkerCtx,
    rebuild: &dyn Fn() -> Result<Transformer>,
) {
    let cfg = &ctx.cfg;
    let max_slots = cfg.batch.max_slots.max(1);
    let prefill_chunk = cfg.batch.prefill_chunk.max(1);
    model.ensure_slots(max_slots);
    // The idle pickup must never admit more requests than there are
    // slots to hold them.
    let policy = BatchPolicy { max_batch: cfg.batch.max_batch.min(max_slots), ..cfg.batch };
    let batcher = Batcher::new(Arc::clone(&ctx.queue), policy);
    let mut rng = Rng::new(0xC0FFEE);
    let sampler = Sampler::Greedy;
    let max_seq = model.config().max_seq_len;
    let vocab = model.config().vocab_size;
    let mut slots: Vec<Option<SlotState>> = (0..max_slots).map(|_| None).collect();
    let mut step_slots: Vec<usize> = Vec::with_capacity(max_slots);
    let mut step_tokens: Vec<u32> = Vec::with_capacity(max_slots * prefill_chunk);
    let mut step_counts: Vec<usize> = Vec::with_capacity(max_slots);
    let mut len_after: Vec<usize> = Vec::with_capacity(max_slots);
    let mut retired: Vec<usize> = Vec::with_capacity(max_slots);
    // Panic-quarantined requests awaiting their clean re-run; they
    // re-seat ahead of fresh queue pickups (they already held slots).
    let mut carryover: Vec<Request> = Vec::new();
    // Worker-local seating order for youngest-first eviction.
    let mut seat_counter: u64 = 0;
    loop {
        ctx.beat();
        let live = slots.iter().filter(|s| s.is_some()).count();
        // Admission: block when idle (same idle/shutdown semantics as
        // the sequential loop); top up free slots without waiting while
        // sequences are in flight.
        let mut admitted: Vec<Request> = std::mem::take(&mut carryover);
        if live == 0 && admitted.is_empty() {
            if ctx.shutdown.load(Ordering::Relaxed) && ctx.queue.is_empty() {
                break;
            }
            let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
                if ctx.queue.is_closed() && ctx.queue.is_empty() {
                    break;
                }
                continue;
            };
            admitted = batch.requests;
        } else {
            let free = (max_slots - live).saturating_sub(admitted.len());
            admitted.extend(batcher.poll(free));
        }
        for request in schedule(admitted, cfg.schedule) {
            // Slot-assignment lifecycle checkpoint: a request that
            // expired or was abandoned while queued never takes a slot.
            if let Some(outcome) = preflight(&request) {
                if !respond_terminal(ctx, &request, outcome) {
                    return;
                }
                continue;
            }
            if request.prompt.is_empty() {
                if !respond_terminal(ctx, &request, Retire::Failed("empty prompt".into()))
                {
                    return;
                }
                continue;
            }
            // Seating reservation (the slot-assignment checkpoint's
            // memory analog): the prompt's full page need must be
            // grantable right now, or the request is shed with the
            // named budget error instead of being seated into certain
            // mid-prefill eviction. A no-op on an unbudgeted pool.
            let needed = ctx.n_layers * ctx.kv_pool.pages_for(request.prompt.len());
            if !ctx.kv_pool.can_reserve(needed) {
                ctx.kv_pool.record_reservation_failed();
                if !respond_terminal(
                    ctx,
                    &request,
                    Retire::KvBudget(format!(
                        "seating reservation refused: prompt needs {needed} pages, \
                         {} available",
                        ctx.kv_pool.available()
                    )),
                ) {
                    return;
                }
                continue;
            }
            let free = slots
                .iter()
                .position(|s| s.is_none())
                .expect("admission is capped at the free-slot count");
            model.reset_slot(free);
            let picked_up = Instant::now();
            seat_counter += 1;
            ctx.live_slots.fetch_add(1, Ordering::Relaxed);
            let trace = ctx.trace.as_ref().map(|_| {
                let mut b =
                    TraceBuilder::new(request.id, us_since(ctx.epoch, request.arrival));
                b.seated(us_since(ctx.epoch, picked_up));
                b
            });
            let next_input = request.prompt[0];
            slots[free] = Some(SlotState {
                picked_up,
                next_input,
                prompt_pos: 0,
                tokens: Vec::with_capacity(request.max_new_tokens),
                seated_seq: seat_counter,
                prefill_done: None,
                trace,
                request,
            });
        }
        // Between-step lifecycle checkpoint: an expired or cancelled
        // sequence frees its slot before the next step is assembled.
        for i in 0..max_slots {
            let Some(st) = &slots[i] else { continue };
            let outcome = if st.request.cancel.is_cancelled() {
                Some(Retire::Cancelled)
            } else if st.request.deadline_expired() {
                Some(Retire::Deadline)
            } else {
                None
            };
            if let Some(outcome) = outcome {
                let st = slots[i].take().expect("checked live above");
                // Eager page release: a retired sequence's KV pages go
                // back to the pool at retirement, not at slot reuse.
                model.reset_slot(i);
                if !finish_slot(st, outcome, ctx) {
                    return;
                }
            }
        }
        // KV pressure checkpoint (the between-step sweep's memory
        // analog): estimate the pages the upcoming step will grant —
        // per slot, the page delta of appending its chunk across every
        // layer — and while the pool cannot cover it, retire the
        // **youngest** live slot with the named budget error, freeing
        // its pages immediately. Youngest-first loses the least work
        // and lets the oldest sequences (closest to finishing) keep
        // their pages; the loop terminates because each round either
        // fits or removes a slot. `exhaust_kv_at_step` forces one
        // eviction so chaos tests can drive this deterministically.
        // Cross-worker races (another worker granting pages between
        // this check and the step) surface as a mid-step
        // `KvBudgetExceeded`, handled below — never a panic.
        let mut force_evict =
            fault_exhaust_kv(ctx.step_counter.load(Ordering::Relaxed) + 1, cfg);
        if ctx.kv_pool.is_bounded() || force_evict {
            loop {
                let prefilling = slots
                    .iter()
                    .flatten()
                    .filter(|st| st.prompt_pos < st.request.prompt.len())
                    .count();
                let share =
                    if prefilling == 0 { 1 } else { (prefill_chunk / prefilling).max(1) };
                let mut delta = 0usize;
                for i in 0..max_slots {
                    let Some(st) = &slots[i] else { continue };
                    let seq = model.seq_len_slot(i);
                    let prompt = &st.request.prompt;
                    // Upper bound of this slot's next chunk (invalid-
                    // token truncation can only shrink it — a smaller
                    // step never needs more pages).
                    let take = if st.prompt_pos < prompt.len() {
                        (prompt.len() - st.prompt_pos)
                            .min(share)
                            .min(max_seq.saturating_sub(seq))
                            .max(1)
                    } else {
                        1
                    };
                    delta += ctx.n_layers
                        * (ctx.kv_pool.pages_for(seq + take)
                            - ctx.kv_pool.pages_for(seq));
                }
                if !force_evict && delta <= ctx.kv_pool.available() {
                    break;
                }
                let Some(young) = (0..max_slots)
                    .filter(|&i| slots[i].is_some())
                    .max_by_key(|&i| slots[i].as_ref().map_or(0, |st| st.seated_seq))
                else {
                    break;
                };
                force_evict = false;
                ctx.kv_pool.record_eviction();
                let st = slots[young].take().expect("picked from live slots");
                model.reset_slot(young);
                if !finish_slot(
                    st,
                    Retire::KvBudget("evicted under page pressure (youngest slot)".into()),
                    ctx,
                ) {
                    return;
                }
            }
        }
        // Fair-share chunk budget for this step: `prefill_chunk` total
        // prompt rows, split across the slots currently prefilling
        // (integer share, floor 1 — every slot always advances). With
        // one prefilling slot the full chunk goes to it; with many, no
        // single prompt can monopolize the step.
        let prefilling = slots
            .iter()
            .flatten()
            .filter(|st| st.prompt_pos < st.request.prompt.len())
            .count();
        let share = if prefilling == 0 { 1 } else { (prefill_chunk / prefilling).max(1) };
        // Assemble the ragged step, retiring slots that cannot take
        // another token — a bad request fails alone, never the batch.
        step_slots.clear();
        step_tokens.clear();
        step_counts.clear();
        len_after.clear();
        for i in 0..max_slots {
            let Some(st) = &slots[i] else { continue };
            let prompt = &st.request.prompt;
            let prefill = st.prompt_pos < prompt.len();
            let phase = if prefill { "prefill" } else { "decode" };
            let seq = model.seq_len_slot(i);
            // Validate the first token the step would feed — exactly
            // the failure (and message) the one-token path produced.
            let first = if prefill { prompt[st.prompt_pos] } else { st.next_input };
            let failure = if first as usize >= vocab {
                Some(format!("{phase}: token {first} out of vocab"))
            } else if seq >= max_seq {
                Some(format!("{phase}: sequence exceeds max_seq_len"))
            } else {
                None
            };
            if let Some(msg) = failure {
                let st = slots[i].take().expect("checked live above");
                model.reset_slot(i);
                if !finish_slot(st, Retire::Failed(msg), ctx) {
                    return;
                }
                continue;
            }
            let take = if prefill {
                let mut take =
                    (prompt.len() - st.prompt_pos).min(share).min(max_seq - seq);
                // An invalid token mid-chunk truncates the chunk to the
                // valid prefix: the prefix is consumed exactly as the
                // one-token path would consume it, and the bad token
                // fails on the next step with the same message.
                for (j, &t) in prompt[st.prompt_pos..st.prompt_pos + take]
                    .iter()
                    .enumerate()
                {
                    if t as usize >= vocab {
                        take = j;
                        break;
                    }
                }
                debug_assert!(take >= 1, "first token was validated above");
                step_tokens.extend_from_slice(&prompt[st.prompt_pos..st.prompt_pos + take]);
                take
            } else {
                step_tokens.push(st.next_input);
                1
            };
            step_slots.push(i);
            step_counts.push(take);
            len_after.push(seq + take);
        }
        if step_slots.is_empty() {
            continue;
        }
        let step_no = ctx.step_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let t0 = Instant::now();
        // The supervised section: a panic anywhere inside the model
        // step is caught, converted to per-slot terminal outcomes, and
        // followed by a model rebuild — never a hung waiter.
        let step_res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fault_before_step(step_no, cfg);
            model.forward_chunk(&step_tokens, &step_slots, &step_counts)
        }));
        let logits = match step_res {
            Ok(Ok(l)) => l,
            Ok(Err(e)) => {
                // Per-slot preconditions were checked above, so a step
                // failure is either the cross-worker KV race (another
                // worker granted the pages this step had headroom for
                // — retire the step's rows with the named budget
                // error) or an engine-bug class (fail them loudly).
                // Either way every row reaches a terminal outcome and
                // its partial KV state is released.
                let budget_race = matches!(e, Error::KvBudgetExceeded(_));
                for &i in &step_slots {
                    let st = slots[i].take().expect("was in the step");
                    model.reset_slot(i);
                    if budget_race {
                        ctx.kv_pool.record_eviction();
                    }
                    if !finish_slot(st, retire_for_model_error(&e, "step"), ctx) {
                        return;
                    }
                }
                continue;
            }
            Err(payload) => {
                if !supervise_panic(payload, &mut slots, &step_slots, &mut carryover, ctx)
                {
                    return;
                }
                match rebuild() {
                    Ok(m) => {
                        model = m;
                        model.ensure_slots(max_slots);
                    }
                    Err(e) => {
                        crate::log!(
                            Level::Error,
                            "model rebuild after panic failed err={e}"
                        );
                        for r in carryover.drain(..) {
                            if !respond_terminal(
                                ctx,
                                &r,
                                Retire::Failed(format!(
                                    "worker rebuild failed after panic: {e}"
                                )),
                            ) {
                                return;
                            }
                        }
                        return;
                    }
                }
                continue;
            }
        };
        let step_dur = t0.elapsed();
        ctx.beat();
        // Advance every slot: prefill consumes its chunk silently; the
        // step that feeds the final prompt token samples the first
        // generated one from the chunk's **last row** (exactly
        // `run_request`'s sequencing, per slot).
        //
        // One trace timestamp per step, shared across every slot: the
        // events record step granularity, not per-slot skew, and the
        // hot loop pays a single `Instant::now()` when tracing is on
        // (zero when off).
        let trace_now = ctx.trace_now_us();
        retired.clear();
        let mut row0 = 0usize;
        for (idx, &i) in step_slots.iter().enumerate() {
            let c = step_counts[idx];
            let last_row = row0 + c - 1;
            row0 += c;
            let st = slots[i].as_mut().expect("was in the step");
            let was_prefill = st.prompt_pos < st.request.prompt.len();
            if was_prefill {
                st.prompt_pos += c;
                if let (Some(t), Some(b)) = (trace_now, st.trace.as_mut()) {
                    b.prefill_chunk(t, c as u32);
                }
                if st.prompt_pos < st.request.prompt.len() {
                    continue; // mid-prefill: logits unused
                }
                // This step consumed the final prompt token.
                st.prefill_done = Some(Instant::now());
                if st.request.max_new_tokens == 0 {
                    retired.push(i);
                    continue;
                }
            }
            let next =
                sampler.sample(&logits[last_row * vocab..(last_row + 1) * vocab], &mut rng);
            st.tokens.push(next);
            // Streaming: every sampled token ships immediately as a
            // `Token` frame; the terminal `Done` still carries the full
            // sequence, so non-streaming consumers see no difference.
            // A dropped receiver surfaces at the terminal send.
            if st.request.stream {
                let _ = ctx.tx.send(Frame::Token {
                    id: st.request.id,
                    index: st.tokens.len() - 1,
                    token: next,
                });
            }
            if let (Some(t), Some(b)) = (trace_now, st.trace.as_mut()) {
                if was_prefill {
                    b.first_token(t);
                } else {
                    b.decode_step(t);
                }
            }
            if st.tokens.len() >= st.request.max_new_tokens
                || next == crate::model::tokenizer::EOS
                || len_after[idx] >= max_seq
            {
                retired.push(i);
            } else {
                st.next_input = next;
            }
        }
        ctx.metrics.record_decode_step(step_slots.len(), step_dur);
        for &i in &retired {
            let st = slots[i].take().expect("retired from the step");
            // Eager page release at completion, so a drained engine
            // holds zero pages and waiting admissions see the headroom
            // without waiting for slot reuse.
            model.reset_slot(i);
            if !finish_slot(st, Retire::Done, ctx) {
                return;
            }
        }
    }
}

/// Run one request to a terminal outcome on the sequential path. The
/// deadline and cancellation are checked between every model step
/// (prefill tokens included), matching the continuous loop's
/// between-step checkpoint. Streaming requests ship each sampled token
/// as a [`Frame::Token`] through `ctx.tx` (the terminal `Done` is sent
/// by the caller's accounting path, as everywhere else).
fn run_request(
    model: &mut Transformer,
    request: &Request,
    rng: &mut Rng,
    ctx: &WorkerCtx,
) -> (Response, Retire) {
    let picked_up = Instant::now();
    let queue_time = picked_up.duration_since(request.arrival);

    model.reset();
    let mut timing = Timing { queue: queue_time, ..Timing::default() };

    let lifecycle = |r: &Request| -> Option<(Response, Retire)> {
        if r.cancel.is_cancelled() {
            return Some((
                Response::err_coded(r.id, "cancelled by client", "cancelled"),
                Retire::Cancelled,
            ));
        }
        if r.deadline_expired() {
            return Some((
                Response::err_coded(r.id, "deadline exceeded", "deadline_exceeded"),
                Retire::Deadline,
            ));
        }
        None
    };

    // Prefill.
    let t0 = Instant::now();
    for &t in &request.prompt {
        if let Some(out) = lifecycle(request) {
            return out;
        }
        if let Err(e) = model.forward_token(t) {
            let outcome = retire_for_model_error(&e, "prefill");
            let msg = outcome.error_message().unwrap_or_default();
            return (Response::err_coded(request.id, msg, outcome.code()), outcome);
        }
    }
    timing.prefill = t0.elapsed();
    if request.prompt.is_empty() {
        return (
            Response::err(request.id, "empty prompt"),
            Retire::Failed("empty prompt".into()),
        );
    }

    // Decode (greedy — the §5.3 equality-comparable setting).
    let t0 = Instant::now();
    let mut tokens = Vec::with_capacity(request.max_new_tokens);
    let sampler = Sampler::Greedy;
    for _ in 0..request.max_new_tokens {
        if let Some(out) = lifecycle(request) {
            return out;
        }
        let logits = match model_logits(model) {
            Ok(l) => l,
            Err(e) => {
                let msg = format!("decode: {e}");
                return (Response::err(request.id, msg.clone()), Retire::Failed(msg));
            }
        };
        let next = sampler.sample(&logits, rng);
        tokens.push(next);
        if request.stream {
            let _ = ctx.tx.send(Frame::Token {
                id: request.id,
                index: tokens.len() - 1,
                token: next,
            });
        }
        if next == crate::model::tokenizer::EOS
            || model.seq_len() >= model.config().max_seq_len
        {
            break;
        }
        if let Err(e) = model.forward_token(next) {
            let msg = format!("decode: {e}");
            return (Response::err(request.id, msg.clone()), Retire::Failed(msg));
        }
    }
    timing.decode = t0.elapsed();
    (Response::ok(request.id, tokens, timing), Retire::Done)
}

fn model_logits(model: &Transformer) -> Result<Vec<f32>> {
    // The logits of the last forward pass live in the model; we copy
    // them because sampling mutates nothing but we need ownership.
    Ok(model.last_logits().to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_engine(cfg: EngineConfig) -> InferenceEngine {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        InferenceEngine::start(weights, cfg).unwrap()
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(resp.id, 1);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.timing.total() > Duration::ZERO);
        engine.shutdown();
    }

    #[test]
    fn multiple_workers_serve_many_requests() {
        let engine = tiny_engine(EngineConfig { workers: 3, ..Default::default() });
        for i in 0..12 {
            engine.submit(Request::new(i, vec![1 + i as u32, 2, 3], 3)).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("resp");
            assert!(r.error.is_none());
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 12);
        engine.shutdown();
    }

    #[test]
    fn continuous_and_sequential_engines_agree_token_for_token() {
        // The batched-decode acceptance check at the engine level:
        // greedy responses from a continuous-batching engine must match
        // a strictly sequential (`max_slots == 1`) engine per request.
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let prompts: Vec<Vec<u32>> =
            (0..6u32).map(|i| vec![10 + i, 20, 30 + (i % 3)]).collect();
        // `prefill_chunk: 1` alongside `max_slots: 1` pins the strictly
        // sequential worker loop (the default chunk of 8 would route a
        // single slot through the continuous loop, and this test exists
        // to compare the two loops, not the continuous loop to itself).
        let run = |max_slots: usize, prefill_chunk: usize| -> Vec<Vec<u32>> {
            let engine = InferenceEngine::start(
                Arc::clone(&weights),
                EngineConfig {
                    workers: 1,
                    batch: BatchPolicy { max_slots, prefill_chunk, ..Default::default() },
                    ..Default::default()
                },
            )
            .unwrap();
            for (i, p) in prompts.iter().enumerate() {
                engine.submit(Request::new(i as u64, p.clone(), 6)).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> = (0..prompts.len())
                .map(|_| {
                    let r =
                        engine.recv_timeout(Duration::from_secs(60)).expect("response");
                    assert!(r.error.is_none(), "{:?}", r.error);
                    (r.id, r.tokens)
                })
                .collect();
            engine.shutdown();
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, t)| t).collect()
        };
        let sequential = run(1, 1);
        assert_eq!(run(4, 8), sequential, "batched+chunked decode must match sequential");
        assert_eq!(run(4, 1), sequential, "batched unchunked decode must match sequential");
    }

    #[test]
    fn batched_engine_reports_occupancy_above_one() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        for i in 0..8 {
            engine.submit(Request::new(i, vec![5 + i as u32, 6, 7], 24)).unwrap();
        }
        for _ in 0..8 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        let snap = engine.metrics().snapshot();
        assert!(snap.get("decode_steps").unwrap().as_f64().unwrap() > 0.0);
        let occ = snap.get("batch_occupancy_mean").unwrap().as_f64().unwrap();
        assert!(occ > 1.0, "8 concurrent requests must batch (occupancy {occ})");
        assert!(snap.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
        engine.shutdown();
    }

    #[test]
    fn serves_from_packed_plan_artifacts() {
        use crate::kernels::artifact::{ternary_fingerprint, PlanArtifact};
        use crate::kernels::index::TernaryRsrIndex;
        use crate::kernels::optimal_k::optimal_k_rsrpp;

        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let dir = std::env::temp_dir()
            .join(format!("rsr-engine-plans-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, m, scale) in weights.named_matrices() {
            let k = optimal_k_rsrpp(m.rows());
            let art = PlanArtifact::ternary(
                name.clone(),
                TernaryRsrIndex::preprocess(m, k),
                scale,
            )
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m));
            art.save(dir.join(format!("{name}.rsrz"))).unwrap();
        }

        let engine = InferenceEngine::start(
            Arc::clone(&weights),
            EngineConfig { workers: 2, plan_dir: Some(dir.clone()), ..Default::default() },
        )
        .unwrap();
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        engine.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_dir_requires_rsrpp_backend() {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let res = InferenceEngine::start(
            weights,
            EngineConfig {
                backend: Backend::Standard,
                plan_dir: Some(std::path::PathBuf::from("/nonexistent")),
                ..Default::default()
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            queue_capacity: 1,
            ..Default::default()
        });
        // Stuff the queue beyond capacity; at least one must be rejected.
        let mut rejected = 0;
        for i in 0..20 {
            if engine.submit(Request::new(i, vec![5; 16], 8)).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // Drain what was admitted.
        while engine.recv_timeout(Duration::from_secs(10)).is_some() {
            if engine.inflight() == 0 {
                break;
            }
        }
        engine.shutdown();
    }

    #[test]
    fn malformed_request_yields_error_response() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        // Out-of-vocab token → prefill error, engine survives.
        engine.submit(Request::new(5, vec![999_999], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_some());
        // Engine still serves afterwards.
        engine.submit(Request::new(6, vec![10], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none());
        engine.shutdown();
    }

    // ---- lifecycle: deadlines ------------------------------------

    #[test]
    fn expired_deadline_is_shed_at_admission() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        let req = Request::new(1, vec![10, 20], 4).with_deadline(Duration::ZERO);
        match engine.submit(req) {
            Err(Error::DeadlineExceeded(_)) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(engine.metrics().deadline_exceeded.load(Ordering::Relaxed), 1);
        assert_eq!(engine.inflight(), 0, "shed work must not count inflight");
        engine.shutdown();
    }

    #[test]
    fn deadline_expiring_mid_generation_retires_the_slot() {
        // A 16-token prompt at the default prefill_chunk of 8 needs at
        // least two lockstep steps; stalling step 1 for 300 ms
        // guarantees the 100 ms deadline expires while the request is
        // mid-flight, so the between-step sweep retires it (or, if the
        // worker was slow to seat it, the slot-assignment preflight
        // sheds it — same terminal outcome either way).
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            fault: FaultPlan { stall_at_step: Some((1, 300)), ..Default::default() },
            ..Default::default()
        });
        let req = Request::new(7, (10u32..26).collect(), 8)
            .with_deadline(Duration::from_millis(100));
        engine.submit(req).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal outcome");
        assert_eq!(r.id, 7);
        assert!(r.error.is_some(), "must be retired with an error");
        assert_eq!(r.code, Some("deadline_exceeded"));
        assert_eq!(engine.metrics().deadline_exceeded.load(Ordering::Relaxed), 1);
        assert_eq!(engine.inflight(), 0);
        // The slot is free again: a healthy request completes.
        engine.submit(Request::new(8, vec![10, 20], 3)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        engine.shutdown();
    }

    #[test]
    fn deadline_on_sequential_path_retires_too() {
        // The stall fires inside the supervised section just before
        // `run_request`; by the time the request's first per-token
        // lifecycle check runs, the 100 ms deadline has long expired.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            batch: BatchPolicy { max_slots: 1, prefill_chunk: 1, ..Default::default() },
            fault: FaultPlan { stall_at_step: Some((1, 300)), ..Default::default() },
            ..Default::default()
        });
        let req = Request::new(3, vec![10, 20, 30], 8)
            .with_deadline(Duration::from_millis(100));
        engine.submit(req).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal outcome");
        assert_eq!(r.code, Some("deadline_exceeded"));
        assert_eq!(engine.inflight(), 0);
        engine.shutdown();
    }

    // ---- lifecycle: cancellation ---------------------------------

    #[test]
    fn cancelled_request_frees_its_slot() {
        // Step 1 stalls 400 ms; the cancel lands 100 ms in — during
        // the stalled step (or before pickup, if the worker was slow) —
        // so the between-step sweep (or the preflight) retires the
        // request with the cancellation error, never an Ok response.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            fault: FaultPlan { stall_at_step: Some((1, 400)), ..Default::default() },
            ..Default::default()
        });
        let req = Request::new(9, (10u32..26).collect(), 8);
        let token = req.cancel.clone();
        engine.submit(req).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        token.cancel();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal outcome");
        assert_eq!(r.id, 9);
        assert!(r.error.is_some(), "cancelled requests get an error response");
        assert_eq!(r.code, Some("cancelled"));
        assert_eq!(engine.metrics().cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(engine.inflight(), 0);
        engine.shutdown();
    }

    #[test]
    fn cancelled_before_admission_is_rejected() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        let req = Request::new(4, vec![10], 4);
        req.cancel.cancel();
        match engine.submit(req) {
            Err(Error::Cancelled(_)) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        assert_eq!(engine.inflight(), 0);
        engine.shutdown();
    }

    // ---- lifecycle: supervision ----------------------------------

    #[test]
    fn worker_panic_yields_terminal_outcomes_and_worker_survives() {
        // Panic injected at engine step 2. Wherever it lands (normally
        // mid-decode of the first request; mid-prefill of the second if
        // the first happened to finish in one step), supervision must
        // convert it into terminal outcomes: every request gets exactly
        // one response, inflight drains to zero, `panics_total` counts
        // the catch, and the rebuilt worker keeps serving.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            fault: FaultPlan { panic_at_steps: vec![2], ..Default::default() },
            ..Default::default()
        });
        engine.submit(Request::new(1, vec![10, 20, 30], 8)).unwrap();
        let r1 = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        engine.submit(Request::new(2, vec![11, 21, 31], 8)).unwrap();
        let r2 = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        // At most one of the two can have died in the panic (a
        // mid-prefill hit is retried and completes); the error, when
        // present, names the panic.
        let errs: Vec<&String> =
            [&r1, &r2].iter().filter_map(|r| r.error.as_ref()).collect();
        assert!(errs.len() <= 1, "{errs:?}");
        for e in &errs {
            assert!(e.contains("panicked"), "{e}");
        }
        assert_eq!(engine.panics_total(), 1, "the step-2 panic is caught exactly once");
        assert_eq!(engine.inflight(), 0, "no leaked inflight after a panic");
        // The worker rebuilt its model and keeps serving.
        engine.submit(Request::new(50, vec![10, 20], 3)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        engine.shutdown();
    }

    #[test]
    fn panic_mid_prefill_quarantines_and_retries_once() {
        // prefill_chunk 1 + an 8-token prompt → steps 1..8 are prefill;
        // the panic at step 3 hits mid-prefill, the request retries
        // cleanly and completes.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            batch: BatchPolicy { max_slots: 2, prefill_chunk: 1, ..Default::default() },
            fault: FaultPlan { panic_at_steps: vec![3], ..Default::default() },
            ..Default::default()
        });
        engine.submit(Request::new(1, vec![10, 20, 30, 40, 50, 60, 70, 80], 4)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        assert!(r.error.is_none(), "retried request must complete: {:?}", r.error);
        assert!(!r.tokens.is_empty());
        assert_eq!(engine.panics_total(), 1);
        assert_eq!(engine.inflight(), 0);
        engine.shutdown();
    }

    #[test]
    fn second_panic_poisons_the_request() {
        // Panic at steps 2 and 3: the first attempt dies at step 2
        // (mid-prefill → quarantine retry), the retry dies at step 3 →
        // poisoned, with a terminal error response.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            batch: BatchPolicy { max_slots: 2, prefill_chunk: 1, ..Default::default() },
            fault: FaultPlan { panic_at_steps: vec![2, 3], ..Default::default() },
            ..Default::default()
        });
        engine.submit(Request::new(2, vec![10, 20, 30, 40, 50, 60, 70, 80], 4)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        let err = r.error.expect("twice-panicking request must be poisoned");
        assert!(err.contains("poisoned"), "{err}");
        assert_eq!(engine.panics_total(), 2);
        assert_eq!(engine.inflight(), 0);
        // Engine still healthy afterwards.
        engine.submit(Request::new(3, vec![10, 20], 3)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        engine.shutdown();
    }

    #[test]
    fn sequential_path_supervises_panics_too() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            batch: BatchPolicy { max_slots: 1, prefill_chunk: 1, ..Default::default() },
            // Sequential steps number per request: the first request
            // panics on both its attempts → poisoned.
            fault: FaultPlan { panic_at_steps: vec![1, 2], ..Default::default() },
            ..Default::default()
        });
        engine.submit(Request::new(1, vec![10, 20], 4)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        assert!(r.error.unwrap().contains("poisoned"));
        assert_eq!(engine.panics_total(), 2);
        // Worker survived; next request is fine.
        engine.submit(Request::new(2, vec![10, 20], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(engine.inflight(), 0);
        engine.shutdown();
    }

    // ---- lifecycle: heartbeat / fault plumbing -------------------

    #[test]
    fn heartbeat_stays_fresh_on_an_idle_engine() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        std::thread::sleep(Duration::from_millis(150));
        assert!(
            engine.heartbeat_age() < Duration::from_millis(120),
            "idle workers must keep beating (age {:?})",
            engine.heartbeat_age()
        );
        engine.shutdown();
    }

    // ---- observability: traces / profiling / conservation --------

    #[test]
    fn trace_ring_records_complete_timelines() {
        // Threshold 0 pins every request into the slow log, so the
        // test can assert on a deterministic retained timeline.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            trace_slow_ms: Some(0),
            ..Default::default()
        });
        engine.submit(Request::new(41, vec![10, 20, 30], 4)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(r.error.is_none(), "{:?}", r.error);
        let snap = engine.trace_snapshot().expect("tracing is on");
        let slow = snap.get("slow").unwrap().as_arr().unwrap();
        assert_eq!(slow.len(), 1, "threshold 0 pins the request");
        let t = &slow[0];
        assert_eq!(t.get("id").unwrap().as_f64(), Some(41.0));
        assert_eq!(t.get("outcome").unwrap().as_str(), Some("completed"));
        let events = t.get("events").unwrap().as_arr().unwrap();
        let kinds: Vec<&str> =
            events.iter().map(|e| e.get("event").unwrap().as_str().unwrap()).collect();
        assert_eq!(kinds.first(), Some(&"admitted"));
        assert_eq!(kinds.get(1), Some(&"seated"));
        assert_eq!(kinds.last(), Some(&"terminal"));
        assert!(kinds.contains(&"first_token"), "{kinds:?}");
        // Timestamps are monotone within the coalesced event stream.
        let ts: Vec<f64> =
            events.iter().map(|e| e.get("t_us").unwrap().as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        engine.shutdown();
    }

    #[test]
    fn shed_requests_trace_and_conserve() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            trace_slow_ms: Some(10_000),
            ..Default::default()
        });
        let req = Request::new(7, vec![10, 20], 4).with_deadline(Duration::ZERO);
        assert!(engine.submit(req).is_err());
        // A shed is terminal (non-completed) → pinned regardless of
        // the 10 s threshold.
        let snap = engine.trace_snapshot().unwrap();
        let slow = snap.get("slow").unwrap().as_arr().unwrap();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].get("outcome").unwrap().as_str(), Some("deadline_exceeded"));
        // The shed counted as admitted-with-immediate-terminal:
        // conservation holds with zero inflight.
        let m = engine.snapshot();
        assert_eq!(m.get("admitted").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("inflight").unwrap().as_f64(), Some(0.0));
        assert!(matches!(m.get("conserved"), Some(Json::Bool(true))));
        engine.shutdown();
    }

    #[test]
    fn layer_profile_attributes_decode_time() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            profile_layers: true,
            ..Default::default()
        });
        engine.submit(Request::new(1, vec![10, 20, 30], 6)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(r.error.is_none(), "{:?}", r.error);
        let snap = engine.snapshot();
        let layers = snap.get("layers").expect("--profile-layers adds rows").as_arr().unwrap();
        assert!(!layers.is_empty());
        let names: Vec<&str> =
            layers.iter().map(|l| l.get("layer").unwrap().as_str().unwrap()).collect();
        assert!(names.contains(&"lm_head"), "{names:?}");
        assert!(names.iter().any(|n| n.ends_with(".gate")), "{names:?}");
        assert!(names.iter().any(|n| n.ends_with(".wq")), "{names:?}");
        for l in layers {
            assert!(l.get("count").unwrap().as_f64().unwrap() > 0.0);
            assert!(l.get("total_ns").unwrap().as_f64().unwrap() > 0.0);
        }
        engine.shutdown();
    }

    #[test]
    fn profiling_off_adds_no_layer_rows() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(r.error.is_none());
        assert!(engine.snapshot().get("layers").is_none());
        assert!(engine.trace_snapshot().is_none(), "tracing defaults off");
        engine.shutdown();
    }

    #[test]
    fn live_slots_drains_to_zero() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        for i in 0..6 {
            engine.submit(Request::new(i, vec![10 + i as u32, 20], 8)).unwrap();
        }
        for _ in 0..6 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(engine.live_slots(), 0, "all slots retired");
        assert!(engine.uptime() > Duration::ZERO);
        assert_eq!(engine.queue_depth(), 0);
        engine.shutdown();
    }

    #[test]
    fn forced_queue_full_rejects_and_counts() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            fault: FaultPlan { force_queue_full: true, ..Default::default() },
            ..Default::default()
        });
        let err = engine.submit(Request::new(1, vec![10], 2)).unwrap_err();
        assert!(matches!(err, Error::QueueFull(_)), "{err:?}");
        assert_eq!(err.code(), "queue_full");
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.get("rejected_total").unwrap().as_f64(), Some(1.0));
        engine.shutdown();
    }

    // ---- streaming -----------------------------------------------

    #[test]
    fn streaming_frames_reassemble_to_the_response_tokens() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 6).with_stream(true)).unwrap();
        let mut streamed = Vec::new();
        let done = loop {
            match engine.recv_frame_timeout(Duration::from_secs(30)).expect("frame") {
                Frame::Token { id, index, token } => {
                    assert_eq!(id, 1);
                    assert_eq!(index, streamed.len(), "frames arrive in order");
                    streamed.push(token);
                }
                Frame::Done(r) => break r,
            }
        };
        assert!(done.error.is_none(), "{:?}", done.error);
        assert!(!done.tokens.is_empty());
        assert_eq!(streamed, done.tokens, "frames must reassemble exactly");
        engine.shutdown();
    }

    #[test]
    fn streaming_matches_non_streaming_token_for_token() {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let run = |stream: bool| -> Vec<u32> {
            let engine = InferenceEngine::start(
                Arc::clone(&weights),
                EngineConfig { workers: 1, ..Default::default() },
            )
            .unwrap();
            engine
                .submit(Request::new(1, vec![10, 20, 30], 6).with_stream(stream))
                .unwrap();
            let r = engine.recv_timeout(Duration::from_secs(30)).expect("response");
            assert!(r.error.is_none(), "{:?}", r.error);
            engine.shutdown();
            r.tokens
        };
        assert_eq!(run(true), run(false), "streaming must not perturb sampling");
    }

    // ---- drain ----------------------------------------------------

    #[test]
    fn drain_completes_queued_work_and_refuses_new() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        engine.set_draining();
        let err = engine.submit(Request::new(2, vec![10], 2)).unwrap_err();
        assert!(matches!(err, Error::Draining(_)), "{err:?}");
        assert_eq!(err.code(), "draining");
        let r =
            engine.recv_timeout(Duration::from_secs(30)).expect("in-flight completes");
        assert!(r.error.is_none(), "{:?}", r.error);
        let deadline = Instant::now() + Duration::from_secs(10);
        while !engine.drained() {
            assert!(Instant::now() < deadline, "engine must reach drained()");
            std::thread::sleep(Duration::from_millis(5));
        }
        // The refused submit is a rejection (never admitted), so
        // conservation holds with zero inflight at exit.
        let m = engine.snapshot();
        assert_eq!(m.get("rejected_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("inflight").unwrap().as_f64(), Some(0.0));
        assert!(matches!(m.get("conserved"), Some(Json::Bool(true))));
        assert!(matches!(m.get("draining"), Some(Json::Bool(true))));
        engine.shutdown();
    }

    // ---- memory governance: KV budget ----------------------------

    /// Poll until the engine's pool reads zero pages in use. Terminal
    /// responses are sent before (or concurrently with) the page
    /// release on the panic-rebuild path, so a bounded wait is the
    /// honest assertion.
    fn assert_pool_drains(engine: &InferenceEngine) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while engine.kv_pool().pages_in_use() != 0 {
            assert!(
                Instant::now() < deadline,
                "pool held {} page(s) after every request retired",
                engine.kv_pool().pages_in_use()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    #[test]
    fn oversized_prompt_is_shed_at_admission_with_the_named_budget_error() {
        // tiny: kv_dim = 2 kv-heads × 16 head-dim = 32 floats, so a
        // 4-token page is 2·4·32·4 = 1024 bytes; a 2048-byte budget
        // holds 2 pages. A 16-token prompt needs 4 pages × 2 layers =
        // 8 — impossible even on an empty pool → admission sheds it.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            kv_budget: Some(2048),
            kv_page_tokens: 4,
            ..Default::default()
        });
        assert_eq!(engine.kv_pool().total_pages(), 2);
        let err = engine.submit(Request::new(1, (10u32..26).collect(), 4)).unwrap_err();
        match &err {
            Error::KvBudgetExceeded(m) => assert!(m.contains("8 KV pages"), "{m}"),
            other => panic!("expected KvBudgetExceeded, got {other:?}"),
        }
        assert_eq!(engine.kv_pool().reservations_failed(), 1);
        assert_eq!(engine.inflight(), 0, "shed work must not count inflight");
        // The shed is a first-class terminal outcome: conservation
        // holds with the kv_budget_exceeded counter carrying it.
        let m = engine.snapshot();
        assert_eq!(m.get("admitted").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("kv_budget_exceeded_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("inflight").unwrap().as_f64(), Some(0.0));
        assert!(matches!(m.get("conserved"), Some(Json::Bool(true))));
        // A prompt that fits still serves: the budget degrades, never
        // disables.
        engine.submit(Request::new(2, vec![10, 20], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_pool_drains(&engine);
        engine.shutdown();
    }

    #[test]
    fn forced_exhaustion_evicts_the_youngest_slot_with_a_terminal_error() {
        // `exhaust_kv_at_step` fires the pressure checkpoint before
        // step 2, while request 1 (and possibly 2) is mid-flight: the
        // youngest live slot is retired with the named budget error —
        // never a panic, never a hang — and everything else completes.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            fault: FaultPlan { exhaust_kv_at_step: Some(2), ..Default::default() },
            ..Default::default()
        });
        engine.submit(Request::new(1, vec![10, 20, 30], 16)).unwrap();
        engine.submit(Request::new(2, vec![11, 21, 31], 16)).unwrap();
        let mut errs = Vec::new();
        for _ in 0..2 {
            let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
            if let Some(e) = r.error {
                assert_eq!(r.code, Some("kv_budget_exceeded"));
                errs.push(e);
            }
        }
        assert_eq!(errs.len(), 1, "exactly one slot is evicted: {errs:?}");
        // The prose discriminates the eviction cause within the coded
        // budget outcome (shed-at-seating vs mid-decode eviction).
        assert!(errs[0].contains("evicted under page pressure"), "{}", errs[0]);
        assert_eq!(engine.kv_pool().evictions(), 1);
        assert_eq!(engine.inflight(), 0);
        let m = engine.snapshot();
        assert_eq!(m.get("kv_budget_exceeded_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("kv_evictions_total").unwrap().as_f64(), Some(1.0));
        assert!(matches!(m.get("conserved"), Some(Json::Bool(true))));
        assert_pool_drains(&engine);
        engine.shutdown();
    }

    #[test]
    fn pool_occupancy_returns_to_zero_after_retirement_and_panic_rebuild() {
        // Every retirement path — completion AND the panic-rebuild —
        // must return all pages: a budgeted engine that leaked pages
        // would brown out after enough panics.
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            kv_budget: Some(64 * 1024),
            kv_page_tokens: 4,
            batch: BatchPolicy { max_slots: 2, prefill_chunk: 1, ..Default::default() },
            fault: FaultPlan { panic_at_steps: vec![3], ..Default::default() },
            ..Default::default()
        });
        // The step-3 panic lands mid-prefill of the 8-token prompt →
        // quarantine retry on a rebuilt model → completes. The old
        // model's pages are released when the rebuild drops it.
        engine.submit(Request::new(1, vec![10, 20, 30, 40, 50, 60, 70, 80], 4)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).expect("terminal");
        assert!(r.error.is_none(), "retried request must complete: {:?}", r.error);
        assert_eq!(engine.panics_total(), 1);
        assert_pool_drains(&engine);
        assert!(engine.kv_pool().peak_pages_in_use() > 0, "pages were actually used");
        // And again for a plain completion, plus a healthy follow-up.
        engine.submit(Request::new(2, vec![10, 20], 3)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(engine.inflight(), 0);
        assert_pool_drains(&engine);
        engine.shutdown();
    }

    #[test]
    fn budgeted_engine_matches_unbudgeted_tokens_exactly() {
        // The acceptance pin for `--kv-budget`: a budget large enough
        // to never shed or evict must serve bit-identical tokens to
        // the unbudgeted engine — paging, reservations and sweeps are
        // invisible to the math.
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        let prompts: Vec<Vec<u32>> =
            (0..5u32).map(|i| vec![10 + i, 20, 30 + (i % 3)]).collect();
        let run = |budget: Option<u64>, page_tokens: usize| -> Vec<Vec<u32>> {
            let engine = InferenceEngine::start(
                Arc::clone(&weights),
                EngineConfig {
                    workers: 1,
                    kv_budget: budget,
                    kv_page_tokens: page_tokens,
                    ..Default::default()
                },
            )
            .unwrap();
            for (i, p) in prompts.iter().enumerate() {
                engine.submit(Request::new(i as u64, p.clone(), 6)).unwrap();
            }
            let mut out: Vec<(u64, Vec<u32>)> = (0..prompts.len())
                .map(|_| {
                    let r =
                        engine.recv_timeout(Duration::from_secs(60)).expect("response");
                    assert!(r.error.is_none(), "{:?}", r.error);
                    (r.id, r.tokens)
                })
                .collect();
            engine.shutdown();
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, t)| t).collect()
        };
        let unbudgeted = run(None, KvPool::DEFAULT_PAGE_TOKENS);
        assert_eq!(
            run(Some(1 << 20), 4),
            unbudgeted,
            "a generous budget with tiny pages must not perturb tokens"
        );
        assert_eq!(
            run(Some(1 << 20), 1),
            unbudgeted,
            "one-token pages must not perturb tokens"
        );
    }
}
