//! The inference engine: worker threads each owning a `Transformer`
//! instance, pulling batches from the shared queue, running
//! prefill → decode per request, and reporting completions.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::queue::{BoundedQueue, PushError};
use super::request::{Request, Response, Timing};
use super::scheduler::{schedule, Policy};
use crate::error::{Error, Result};
use crate::kernels::Backend;
use crate::model::sampler::Sampler;
use crate::model::transformer::Transformer;
use crate::model::weights::ModelWeights;
use crate::util::rng::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (each with its own `Transformer`).
    pub workers: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Batching policy.
    pub batch: BatchPolicy,
    /// Scheduling policy within a batch.
    pub schedule: Policy,
    /// Multiply backend for the model.
    pub backend: Backend,
    /// Blocking parameter (0 → analytic optimum).
    pub k: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            schedule: Policy::default(),
            backend: Backend::RsrPlusPlus,
            k: 0,
        }
    }
}

/// A running engine: submit requests, receive responses.
///
/// The response receiver is Mutex-wrapped so the engine is `Sync`; in
/// multi-consumer settings (the TCP server) a single dispatcher thread
/// should own consumption (see `server::ResponseHub`).
pub struct InferenceEngine {
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    responses: std::sync::Mutex<mpsc::Receiver<Response>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
}

impl InferenceEngine {
    /// Start workers. Model preparation (preprocessing every weight
    /// matrix — paper Algorithm 1) happens here, once, per worker.
    pub fn start(weights: Arc<ModelWeights>, cfg: EngineConfig) -> Result<Self> {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Response>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for wid in 0..cfg.workers.max(1) {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let tx = tx.clone();
            let weights = Arc::clone(&weights);
            let inflight = Arc::clone(&inflight);
            let shutdown = Arc::clone(&shutdown);
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rsr-worker-{wid}"))
                    .spawn(move || {
                        // Preprocess once per worker (fixed weights —
                        // the paper's core observation).
                        let model = match Transformer::from_weights(
                            &weights,
                            cfg.backend,
                            cfg.k,
                        ) {
                            Ok(m) => m,
                            Err(e) => {
                                eprintln!("worker {wid}: model build failed: {e}");
                                return;
                            }
                        };
                        worker_loop(model, queue, metrics, tx, inflight, shutdown, &cfg);
                    })
                    .map_err(|e| Error::Serving(e.to_string()))?,
            );
        }
        Ok(Self {
            queue,
            metrics,
            responses: std::sync::Mutex::new(rx),
            workers,
            inflight,
            shutdown,
        })
    }

    /// Submit a request; fails fast under backpressure.
    pub fn submit(&self, request: Request) -> Result<()> {
        let res = self.queue.try_push(request);
        self.metrics.record_admission(res.is_ok());
        match res {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(PushError::Full) => {
                Err(Error::Serving("queue full — retry later".into()))
            }
            Err(PushError::Closed) => Err(Error::Serving("engine shut down".into())),
        }
    }

    /// Receive the next completed response (blocking with timeout).
    /// Single-consumer: concurrent callers serialize on an internal
    /// lock and may steal each other's responses — multi-connection
    /// fronts must use one dispatcher (see `server::ResponseHub`).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.responses.lock().unwrap().recv_timeout(timeout).ok()
    }

    /// Requests admitted but not yet answered.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Queue depth + inflight, the router's load signal.
    pub fn load(&self) -> usize {
        self.queue.len() + self.inflight()
    }

    /// Metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting work, drain, and join workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    mut model: Transformer,
    queue: Arc<BoundedQueue<Request>>,
    metrics: Arc<Metrics>,
    tx: mpsc::Sender<Response>,
    inflight: Arc<AtomicUsize>,
    shutdown: Arc<AtomicBool>,
    cfg: &EngineConfig,
) {
    let batcher = Batcher::new(Arc::clone(&queue), cfg.batch);
    let mut rng = Rng::new(0xC0FFEE);
    loop {
        if shutdown.load(Ordering::Relaxed) && queue.is_empty() {
            break;
        }
        let Some(batch) = batcher.next_batch(Duration::from_millis(50)) else {
            if queue.is_closed() && queue.is_empty() {
                break;
            }
            continue;
        };
        for request in schedule(batch.requests, cfg.schedule) {
            let response = run_request(&mut model, &request, &mut rng);
            match &response.error {
                None => metrics.record(&response.timing, response.tokens.len()),
                Some(_) => metrics.record_failure(),
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            if tx.send(response).is_err() {
                return; // receiver dropped — engine gone
            }
        }
    }
}

fn run_request(model: &mut Transformer, request: &Request, rng: &mut Rng) -> Response {
    let picked_up = Instant::now();
    let queue_time = picked_up.duration_since(request.arrival);

    model.reset();
    let mut timing = Timing { queue: queue_time, ..Timing::default() };

    // Prefill.
    let t0 = Instant::now();
    for &t in &request.prompt {
        if let Err(e) = model.forward_token(t) {
            return Response::err(request.id, format!("prefill: {e}"));
        }
    }
    timing.prefill = t0.elapsed();
    if request.prompt.is_empty() {
        return Response::err(request.id, "empty prompt");
    }

    // Decode (greedy — the §5.3 equality-comparable setting).
    let t0 = Instant::now();
    let mut tokens = Vec::with_capacity(request.max_new_tokens);
    let sampler = Sampler::Greedy;
    for _ in 0..request.max_new_tokens {
        let logits = match model_logits(model) {
            Ok(l) => l,
            Err(e) => return Response::err(request.id, format!("decode: {e}")),
        };
        let next = sampler.sample(&logits, rng);
        tokens.push(next);
        if next == crate::model::tokenizer::EOS
            || model.seq_len() >= model.config().max_seq_len
        {
            break;
        }
        if let Err(e) = model.forward_token(next) {
            return Response::err(request.id, format!("decode: {e}"));
        }
    }
    timing.decode = t0.elapsed();
    Response::ok(request.id, tokens, timing)
}

fn model_logits(model: &Transformer) -> Result<Vec<f32>> {
    // The logits of the last forward pass live in the model; we copy
    // them because sampling mutates nothing but we need ownership.
    Ok(model.last_logits().to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_engine(cfg: EngineConfig) -> InferenceEngine {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 99).unwrap());
        InferenceEngine::start(weights, cfg).unwrap()
    }

    #[test]
    fn serves_a_request_end_to_end() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
        let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(resp.id, 1);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.timing.total() > Duration::ZERO);
        engine.shutdown();
    }

    #[test]
    fn multiple_workers_serve_many_requests() {
        let engine = tiny_engine(EngineConfig { workers: 3, ..Default::default() });
        for i in 0..12 {
            engine.submit(Request::new(i, vec![1 + i as u32, 2, 3], 3)).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            let r = engine.recv_timeout(Duration::from_secs(60)).expect("resp");
            assert!(r.error.is_none());
            seen.insert(r.id);
        }
        assert_eq!(seen.len(), 12);
        assert_eq!(engine.metrics().completed.load(Ordering::Relaxed), 12);
        engine.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let engine = tiny_engine(EngineConfig {
            workers: 1,
            queue_capacity: 1,
            ..Default::default()
        });
        // Stuff the queue beyond capacity; at least one must be rejected.
        let mut rejected = 0;
        for i in 0..20 {
            if engine.submit(Request::new(i, vec![5; 16], 8)).is_err() {
                rejected += 1;
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        // Drain what was admitted.
        while engine.recv_timeout(Duration::from_secs(10)).is_some() {
            if engine.inflight() == 0 {
                break;
            }
        }
        engine.shutdown();
    }

    #[test]
    fn malformed_request_yields_error_response() {
        let engine = tiny_engine(EngineConfig { workers: 1, ..Default::default() });
        // Out-of-vocab token → prefill error, engine survives.
        engine.submit(Request::new(5, vec![999_999], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_some());
        // Engine still serves afterwards.
        engine.submit(Request::new(6, vec![10], 2)).unwrap();
        let r = engine.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(r.error.is_none());
        engine.shutdown();
    }
}
