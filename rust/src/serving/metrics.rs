//! Serving metrics: counters + per-phase latency histograms, merged
//! across workers and snapshotted as JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Shared metrics sink (one per engine; workers record through it).
#[derive(Default)]
pub struct Metrics {
    /// Requests admitted to the queue.
    pub admitted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Requests retired because their deadline expired (shed from the
    /// queue at slot assignment, or retired mid-generation).
    pub deadline_exceeded: AtomicU64,
    /// Requests retired because the client cancelled (disconnected).
    pub cancelled: AtomicU64,
    /// Requests retired because the KV page pool's byte budget could
    /// not cover them — shed at admission/seating or evicted
    /// youngest-first mid-decode. A terminal outcome, so conservation
    /// (`admitted == terminals + inflight`) holds under budget
    /// pressure exactly as it does under deadline pressure.
    pub kv_budget_exceeded: AtomicU64,
    /// Worker panics caught by supervision (each converts to per-slot
    /// terminal responses and a model rebuild, never a hung waiter).
    pub panics: AtomicU64,
    /// Tokens generated in total.
    pub tokens_out: AtomicU64,
    /// Lockstep decode steps executed (continuous batching; `0` on the
    /// strictly sequential `max_slots == 1` path).
    pub decode_steps: AtomicU64,
    /// Sum of live slots over all decode steps — `/ decode_steps` is
    /// the mean batch occupancy, the direct measure of how much index
    /// amortization the batched kernels are actually getting.
    pub decode_slot_steps: AtomicU64,
    /// Wall nanoseconds spent inside model steps (prefill + decode) —
    /// the denominator of the aggregate tokens/sec figure.
    pub decode_busy_ns: AtomicU64,
    /// Prompt tokens consumed by completed requests — the numerator of
    /// `prefill_tokens_per_sec`, the number chunked prefill moves.
    pub prefill_tokens: AtomicU64,
    /// Wall nanoseconds of per-request prefill (pickup → first token),
    /// summed across requests. Concurrent prefills overlap, so this is
    /// a per-request-experienced denominator, not a busy-time one —
    /// the resulting rate is what a caller observes, conservatively.
    pub prefill_wall_ns: AtomicU64,
    hist: Mutex<Hists>,
}

#[derive(Default)]
struct Hists {
    queue: LatencyHistogram,
    prefill: LatencyHistogram,
    decode: LatencyHistogram,
    /// Admitted → terminal latency over EVERY terminal path — failed,
    /// deadline-exceeded and cancelled requests included, so p99 under
    /// overload reflects the shed traffic, not just the survivors.
    total: LatencyHistogram,
    /// The same `total` observations split by terminal outcome
    /// (indexed by [`OUTCOMES`]) — the `outcome` label of the
    /// Prometheus `rsr_request_total_us` histogram.
    total_by_outcome: [LatencyHistogram; 5],
    /// Time to first token: queue wait + prefill, per completed
    /// request — the latency chunked prefill exists to cut.
    ttft: LatencyHistogram,
}

/// The five terminal outcomes, in `total_by_outcome` index order.
pub const OUTCOMES: [&str; 5] = [
    "completed",
    "failed",
    "deadline_exceeded",
    "cancelled",
    "kv_budget_exceeded",
];

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request's timing. `prompt_tokens` is the
    /// request's consumed prompt length (feeds the TTFT and
    /// prefill-throughput aggregates).
    pub fn record(&self, timing: &super::request::Timing, tokens: usize, prompt_tokens: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(prompt_tokens as u64, Ordering::Relaxed);
        self.prefill_wall_ns
            .fetch_add(timing.prefill.as_nanos() as u64, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.queue.record(timing.queue);
        h.prefill.record(timing.prefill);
        h.decode.record(timing.decode);
        h.total.record(timing.total());
        h.total_by_outcome[0].record(timing.total());
        h.ttft.record(timing.queue + timing.prefill);
    }

    /// Record a failure. `total` is the request's admitted → terminal
    /// wall time (every terminal path enters the total histogram).
    pub fn record_failure(&self, total: Duration) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.total.record(total);
        h.total_by_outcome[1].record(total);
    }

    /// Record a deadline-exceeded retirement with its admitted →
    /// terminal wall time.
    pub fn record_deadline_exceeded(&self, total: Duration) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.total.record(total);
        h.total_by_outcome[2].record(total);
    }

    /// Record a client cancellation with its admitted → terminal wall
    /// time.
    pub fn record_cancelled(&self, total: Duration) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.total.record(total);
        h.total_by_outcome[3].record(total);
    }

    /// Record a KV-budget retirement (admission shed, seating refusal,
    /// or mid-decode eviction) with its admitted → terminal wall time.
    pub fn record_kv_budget_exceeded(&self, total: Duration) {
        self.kv_budget_exceeded.fetch_add(1, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.total.record(total);
        h.total_by_outcome[4].record(total);
    }

    /// Record one supervised worker panic.
    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one lockstep decode step over `live` slots that took
    /// `dur` of model time (the continuous-batching engine calls this
    /// once per step, prefill and decode rows alike).
    pub fn record_decode_step(&self, live: usize, dur: Duration) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_slot_steps.fetch_add(live as u64, Ordering::Relaxed);
        self.decode_busy_ns.fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record queue admission / rejection.
    pub fn record_admission(&self, admitted: bool) {
        if admitted {
            self.admitted.fetch_add(1, Ordering::Relaxed);
        } else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot as JSON (for the `metrics` wire command, the CLI, and
    /// tests). Phase objects carry the raw cumulative buckets so the
    /// Prometheus renderer ([`crate::util::obs::render_prometheus`])
    /// and the JSON consumers read one schema.
    pub fn snapshot(&self) -> Json {
        // Conservation: every admitted request is either terminal or
        // still inflight. Terminal counters are read BEFORE `admitted`
        // — each terminal increment is preceded by its own admitted
        // increment (synchronized through the queue handoff), so this
        // read order keeps the residual non-negative under concurrent
        // traffic.
        let completed = self.completed.load(Ordering::Relaxed);
        let failed = self.failed.load(Ordering::Relaxed);
        let deadline = self.deadline_exceeded.load(Ordering::Relaxed);
        let cancelled = self.cancelled.load(Ordering::Relaxed);
        let kv_budget = self.kv_budget_exceeded.load(Ordering::Relaxed);
        let admitted = self.admitted.load(Ordering::Relaxed);
        let terminal = completed + failed + deadline + cancelled + kv_budget;
        debug_assert!(
            admitted >= terminal,
            "conservation violated: admitted {admitted} < terminal {terminal}"
        );
        let inflight = admitted.saturating_sub(terminal);
        let h = self.hist.lock().unwrap();
        let phase = |hist: &LatencyHistogram| {
            Json::obj(vec![
                ("count", Json::num(hist.count() as f64)),
                ("mean_us", Json::num(hist.mean_us())),
                ("p50_us", Json::num(hist.percentile_us(50.0) as f64)),
                ("p99_us", Json::num(hist.percentile_us(99.0) as f64)),
                ("max_us", Json::num(hist.max_us() as f64)),
                ("sum_us", Json::num(hist.sum_us() as f64)),
                (
                    "buckets",
                    Json::Arr(
                        hist.buckets()
                            .into_iter()
                            .map(|(le, cum)| {
                                Json::Arr(vec![
                                    Json::num(le as f64),
                                    Json::num(cum as f64),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        let steps = self.decode_steps.load(Ordering::Relaxed);
        let slot_steps = self.decode_slot_steps.load(Ordering::Relaxed);
        let busy_ns = self.decode_busy_ns.load(Ordering::Relaxed);
        let tokens = self.tokens_out.load(Ordering::Relaxed);
        // Mean live slots per lockstep step: 1.0 = no batching benefit,
        // max_slots = fully saturated. 0 when the sequential path (or
        // no traffic) ran.
        let occupancy = if steps > 0 { slot_steps as f64 / steps as f64 } else { 0.0 };
        // Generated tokens per second of model-busy time (prefill steps
        // included in the denominator, prompt tokens not in the
        // numerator — a conservative aggregate throughput).
        let tps = if busy_ns > 0 { tokens as f64 / (busy_ns as f64 / 1e9) } else { 0.0 };
        // Prompt tokens per second of per-request prefill wall time —
        // the throughput chunked prefill raises (the TTFT lever).
        let p_tokens = self.prefill_tokens.load(Ordering::Relaxed);
        let p_ns = self.prefill_wall_ns.load(Ordering::Relaxed);
        let ptps = if p_ns > 0 { p_tokens as f64 / (p_ns as f64 / 1e9) } else { 0.0 };
        let total_by_outcome = Json::obj(
            OUTCOMES
                .iter()
                .zip(h.total_by_outcome.iter())
                .map(|(name, hist)| (*name, phase(hist)))
                .collect(),
        );
        Json::obj(vec![
            ("admitted", Json::num(admitted as f64)),
            ("rejected", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(completed as f64)),
            ("failed", Json::num(failed as f64)),
            // Conservation: admitted == completed + failed +
            // deadline_exceeded + cancelled + kv_budget_exceeded +
            // inflight (debug-asserted above; `conserved` lets
            // scrapers check it live).
            ("inflight", Json::num(inflight as f64)),
            ("conserved", Json::Bool(admitted >= terminal)),
            // Lifecycle counters (`_total` naming for dashboards;
            // `rejected_total` mirrors `rejected` — the admission-shed
            // count — under the same convention).
            ("rejected_total", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("deadline_exceeded_total", Json::num(deadline as f64)),
            ("cancelled_total", Json::num(cancelled as f64)),
            ("kv_budget_exceeded_total", Json::num(kv_budget as f64)),
            ("panics_total", Json::num(self.panics.load(Ordering::Relaxed) as f64)),
            ("tokens_out", Json::num(tokens as f64)),
            ("decode_steps", Json::num(steps as f64)),
            ("batch_occupancy_mean", Json::num(occupancy)),
            ("tokens_per_sec", Json::num(tps)),
            ("prefill_tokens", Json::num(p_tokens as f64)),
            ("prefill_tokens_per_sec", Json::num(ptps)),
            ("ttft_us", phase(&h.ttft)),
            ("queue", phase(&h.queue)),
            ("prefill", phase(&h.prefill)),
            ("decode", phase(&h.decode)),
            ("total", phase(&h.total)),
            ("total_by_outcome", total_by_outcome),
        ])
    }
}

/// Tokens/second over a window (helper for bench reports).
pub fn throughput(tokens: u64, elapsed: Duration) -> f64 {
    if elapsed.is_zero() {
        return 0.0;
    }
    tokens as f64 / elapsed.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::Timing;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_admission(true);
        m.record_admission(false);
        m.record(
            &Timing {
                queue: Duration::from_micros(100),
                prefill: Duration::from_micros(200),
                decode: Duration::from_micros(700),
            },
            5,
            16,
        );
        m.record_admission(true);
        m.record_failure(Duration::from_micros(50));
        let snap = m.snapshot();
        assert_eq!(snap.get("admitted").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("rejected").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("failed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("tokens_out").unwrap().as_f64(), Some(5.0));
        // Both terminal paths entered the total histogram: the 1000 µs
        // completion AND the 50 µs failure.
        let total = snap.get("total").unwrap();
        assert_eq!(total.get("count").unwrap().as_f64(), Some(2.0));
        assert!(total.get("max_us").unwrap().as_f64().unwrap() >= 1000.0);
        let by = snap.get("total_by_outcome").unwrap();
        assert_eq!(by.get("completed").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        assert_eq!(by.get("failed").unwrap().get("count").unwrap().as_f64(), Some(1.0));
        // Conservation: 2 admitted == 1 completed + 1 failed + 0 inflight.
        assert_eq!(snap.get("inflight").unwrap().as_f64(), Some(0.0));
        assert!(matches!(snap.get("conserved"), Some(Json::Bool(true))));
        // TTFT = queue + prefill = 300us; 16 prompt tokens over 200us
        // of prefill = 80k tok/s.
        assert_eq!(snap.get("prefill_tokens").unwrap().as_f64(), Some(16.0));
        let ttft = snap.get("ttft_us").unwrap();
        assert_eq!(ttft.get("count").unwrap().as_f64(), Some(1.0));
        let mean = ttft.get("mean_us").unwrap().as_f64().unwrap();
        assert!((250.0..=350.0).contains(&mean), "{mean}");
        let ptps = snap.get("prefill_tokens_per_sec").unwrap().as_f64().unwrap();
        assert!((ptps - 80_000.0).abs() < 1.0, "{ptps}");
    }

    #[test]
    fn decode_steps_yield_occupancy_and_throughput() {
        let m = Metrics::new();
        let snap = m.snapshot();
        assert_eq!(snap.get("batch_occupancy_mean").unwrap().as_f64(), Some(0.0));
        assert_eq!(snap.get("tokens_per_sec").unwrap().as_f64(), Some(0.0));
        // 3 steps at occupancies 4, 3, 1 → mean 8/3.
        m.record_decode_step(4, Duration::from_millis(1));
        m.record_decode_step(3, Duration::from_millis(1));
        m.record_decode_step(1, Duration::from_millis(2));
        m.record_admission(true);
        m.record(&Timing::default(), 8, 4);
        let snap = m.snapshot();
        assert_eq!(snap.get("decode_steps").unwrap().as_f64(), Some(3.0));
        let occ = snap.get("batch_occupancy_mean").unwrap().as_f64().unwrap();
        assert!((occ - 8.0 / 3.0).abs() < 1e-9, "{occ}");
        // 8 tokens over 4ms of busy time → 2000 tok/s.
        let tps = snap.get("tokens_per_sec").unwrap().as_f64().unwrap();
        assert!((tps - 2000.0).abs() < 1.0, "{tps}");
    }

    #[test]
    fn lifecycle_counters_snapshot() {
        let m = Metrics::new();
        m.record_admission(false);
        for _ in 0..3 {
            m.record_admission(true);
        }
        m.record_deadline_exceeded(Duration::from_micros(40));
        m.record_deadline_exceeded(Duration::from_micros(60));
        m.record_cancelled(Duration::from_micros(90));
        m.record_panic();
        let snap = m.snapshot();
        assert_eq!(snap.get("rejected_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("deadline_exceeded_total").unwrap().as_f64(), Some(2.0));
        assert_eq!(snap.get("cancelled_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("kv_budget_exceeded_total").unwrap().as_f64(), Some(0.0));
        assert_eq!(snap.get("panics_total").unwrap().as_f64(), Some(1.0));
        // Every shed path entered the outcome-labelled total
        // histograms — p99 under overload sees the shed traffic.
        let by = snap.get("total_by_outcome").unwrap();
        let count_of = |outcome: &str| {
            by.get(outcome).unwrap().get("count").unwrap().as_f64().unwrap()
        };
        assert_eq!(count_of("deadline_exceeded"), 2.0);
        assert_eq!(count_of("cancelled"), 1.0);
        assert_eq!(count_of("completed"), 0.0);
        assert_eq!(snap.get("total").unwrap().get("count").unwrap().as_f64(), Some(3.0));
        assert_eq!(snap.get("inflight").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn kv_budget_is_a_terminal_outcome_that_conserves() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.record_admission(true);
        }
        m.record(&Timing::default(), 2, 4);
        m.record_kv_budget_exceeded(Duration::from_micros(70));
        m.record_kv_budget_exceeded(Duration::from_micros(120));
        let snap = m.snapshot();
        assert_eq!(snap.get("kv_budget_exceeded_total").unwrap().as_f64(), Some(2.0));
        // 3 admitted == 1 completed + 2 kv_budget_exceeded + 0 inflight.
        assert_eq!(snap.get("inflight").unwrap().as_f64(), Some(0.0));
        assert!(matches!(snap.get("conserved"), Some(Json::Bool(true))));
        let by = snap.get("total_by_outcome").unwrap();
        let kv = by.get("kv_budget_exceeded").unwrap();
        assert_eq!(kv.get("count").unwrap().as_f64(), Some(2.0));
        // Budget retirements entered the total histogram too.
        assert_eq!(snap.get("total").unwrap().get("count").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn snapshot_phase_buckets_are_cumulative() {
        let m = Metrics::new();
        m.record_admission(true);
        m.record(
            &Timing {
                queue: Duration::from_micros(3),
                prefill: Duration::from_micros(5),
                decode: Duration::from_micros(9),
            },
            1,
            1,
        );
        let snap = m.snapshot();
        let buckets = snap.get("total").unwrap().get("buckets").unwrap();
        let arr = buckets.as_arr().unwrap();
        assert_eq!(arr.len(), 25, "one pair per finite bucket");
        let mut prev = 0.0;
        for pair in arr {
            let p = pair.as_arr().unwrap();
            let cum = p[1].as_f64().unwrap();
            assert!(cum >= prev, "buckets must be cumulative");
            prev = cum;
        }
        assert_eq!(prev, 1.0);
        assert_eq!(snap.get("total").unwrap().get("sum_us").unwrap().as_f64(), Some(17.0));
    }

    #[test]
    fn throughput_math() {
        assert_eq!(throughput(100, Duration::from_secs(2)), 50.0);
        assert_eq!(throughput(100, Duration::ZERO), 0.0);
    }
}
