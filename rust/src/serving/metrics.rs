//! Serving metrics: counters + per-phase latency histograms, merged
//! across workers and snapshotted as JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Shared metrics sink (one per engine; workers record through it).
#[derive(Default)]
pub struct Metrics {
    /// Requests admitted to the queue.
    pub admitted: AtomicU64,
    /// Requests rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Tokens generated in total.
    pub tokens_out: AtomicU64,
    hist: Mutex<Hists>,
}

#[derive(Default)]
struct Hists {
    queue: LatencyHistogram,
    prefill: LatencyHistogram,
    decode: LatencyHistogram,
    total: LatencyHistogram,
}

impl Metrics {
    /// Fresh metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed request's timing.
    pub fn record(&self, timing: &super::request::Timing, tokens: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.tokens_out.fetch_add(tokens as u64, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.queue.record(timing.queue);
        h.prefill.record(timing.prefill);
        h.decode.record(timing.decode);
        h.total.record(timing.total());
    }

    /// Record a failure.
    pub fn record_failure(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record queue admission / rejection.
    pub fn record_admission(&self, admitted: bool) {
        if admitted {
            self.admitted.fetch_add(1, Ordering::Relaxed);
        } else {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot as JSON (for the CLI `metrics` output and tests).
    pub fn snapshot(&self) -> Json {
        let h = self.hist.lock().unwrap();
        let phase = |hist: &LatencyHistogram| {
            Json::obj(vec![
                ("count", Json::num(hist.count() as f64)),
                ("mean_us", Json::num(hist.mean_us())),
                ("p50_us", Json::num(hist.percentile_us(50.0) as f64)),
                ("p99_us", Json::num(hist.percentile_us(99.0) as f64)),
                ("max_us", Json::num(hist.max_us() as f64)),
            ])
        };
        Json::obj(vec![
            ("admitted", Json::num(self.admitted.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::num(self.rejected.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(self.completed.load(Ordering::Relaxed) as f64)),
            ("failed", Json::num(self.failed.load(Ordering::Relaxed) as f64)),
            ("tokens_out", Json::num(self.tokens_out.load(Ordering::Relaxed) as f64)),
            ("queue", phase(&h.queue)),
            ("prefill", phase(&h.prefill)),
            ("decode", phase(&h.decode)),
            ("total", phase(&h.total)),
        ])
    }
}

/// Tokens/second over a window (helper for bench reports).
pub fn throughput(tokens: u64, elapsed: Duration) -> f64 {
    if elapsed.is_zero() {
        return 0.0;
    }
    tokens as f64 / elapsed.as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::Timing;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_admission(true);
        m.record_admission(false);
        m.record(
            &Timing {
                queue: Duration::from_micros(100),
                prefill: Duration::from_micros(200),
                decode: Duration::from_micros(700),
            },
            5,
        );
        m.record_failure();
        let snap = m.snapshot();
        assert_eq!(snap.get("admitted").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("rejected").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("failed").unwrap().as_f64(), Some(1.0));
        assert_eq!(snap.get("tokens_out").unwrap().as_f64(), Some(5.0));
        let total = snap.get("total").unwrap();
        assert_eq!(total.get("count").unwrap().as_f64(), Some(1.0));
        assert!(total.get("mean_us").unwrap().as_f64().unwrap() >= 1000.0);
    }

    #[test]
    fn throughput_math() {
        assert_eq!(throughput(100, Duration::from_secs(2)), 50.0);
        assert_eq!(throughput(100, Duration::ZERO), 0.0);
    }
}
