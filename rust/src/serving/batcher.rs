//! Dynamic batching: group queued requests by size *or* deadline,
//! whichever comes first — the standard latency/throughput knob of a
//! serving system (vLLM/Orca style, scaled to this stack).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::queue::FairQueue;
use super::request::Request;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests admitted in one pickup (the initial batch a
    /// worker blocks for when idle).
    pub max_batch: usize,
    /// Maximum time to wait for the batch to fill.
    pub max_wait: Duration,
    /// Concurrent decode slots per worker — the continuous-batching
    /// knob. Each worker steps up to this many sequences in lockstep,
    /// retiring finished ones and admitting queued requests into free
    /// slots mid-flight ([`poll`](Batcher::poll)). `1` serves strictly
    /// sequentially: the exact pre-batching code path, bit-for-bit.
    pub max_slots: usize,
    /// Chunked-prefill knob: how many unconsumed prompt tokens a slot
    /// may feed in one lockstep step, stacked along the batch dimension
    /// of the batched RSR kernels (one shared-index read per layer
    /// covers the whole chunk — the time-to-first-token lever). The
    /// value doubles as the **per-step chunk budget**: the total prompt
    /// rows one step stacks is capped at
    /// `max(prefill_chunk, prefilling slots)` — the fair share
    /// `prefill_chunk / prefilling` per slot, floored at one token so
    /// every slot always advances (with more prefilling slots than
    /// budget, each simply degrades to one-token prefill). One long
    /// prompt therefore cannot starve decoding batchmates. `1` feeds
    /// prompts one
    /// token per step — the exact pre-chunking path. Chunked prefill is
    /// bit-identical to it by construction (and by
    /// `rust/tests/prefill.rs`).
    pub prefill_chunk: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_slots: 8,
            prefill_chunk: 8,
        }
    }
}

/// A group of requests picked up together.
#[derive(Debug)]
pub struct Batch {
    /// The member requests.
    pub requests: Vec<Request>,
    /// When the batch was formed (for queue-time accounting).
    pub formed_at: Instant,
}

/// Pulls requests off the shared fair-admission queue according to a
/// [`BatchPolicy`]. Pickup order is the queue's weighted round-robin
/// over client lanes, so one chatty client cannot fill a whole batch
/// while others wait.
pub struct Batcher {
    queue: Arc<FairQueue>,
    policy: BatchPolicy,
}

impl Batcher {
    /// Batcher over a shared queue.
    pub fn new(queue: Arc<FairQueue>, policy: BatchPolicy) -> Self {
        Self { queue, policy }
    }

    /// Block (up to `idle_timeout`) for the next batch. `None` when the
    /// queue is closed/idle.
    ///
    /// Strategy: block for the first request, then top up until either
    /// the batch is full or `max_wait` has elapsed since the first
    /// pickup — bounding the latency any request pays for batching.
    pub fn next_batch(&self, idle_timeout: Duration) -> Option<Batch> {
        let first = self.queue.pop_timeout(idle_timeout)?;
        let formed_at = Instant::now();
        let mut requests = vec![first];
        while requests.len() < self.policy.max_batch {
            let left = self.policy.max_wait.saturating_sub(formed_at.elapsed());
            if left.is_zero() {
                break;
            }
            match self.queue.pop_timeout(left) {
                Some(r) => requests.push(r),
                None => break,
            }
        }
        Some(Batch { requests, formed_at })
    }

    /// Non-blocking top-up for continuous batching: drain up to `max`
    /// queued requests without waiting. Called every decode step for
    /// the free slots, so joins never stall the live sequences — an
    /// empty queue costs one try-lock, not a `max_wait` pause.
    pub fn poll(&self, max: usize) -> Vec<Request> {
        if max == 0 {
            return Vec::new();
        }
        self.queue.pop_many(max, Duration::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn full_batch_returns_immediately() {
        let q = Arc::new(FairQueue::new(100));
        for i in 0..10 {
            q.try_push(req(i)).unwrap();
        }
        let b = Batcher::new(
            Arc::clone(&q),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10), ..Default::default() },
        );
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 4);
        assert!(t0.elapsed() < Duration::from_secs(1), "must not wait when full");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q = Arc::new(FairQueue::new(100));
        q.try_push(req(0)).unwrap();
        let b = Batcher::new(
            Arc::clone(&q),
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20), ..Default::default() },
        );
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "waited {waited:?}");
        assert!(waited < Duration::from_millis(500));
    }

    #[test]
    fn idle_timeout_returns_none() {
        let q = Arc::new(FairQueue::new(4));
        let b = Batcher::new(Arc::clone(&q), BatchPolicy::default());
        assert!(b.next_batch(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn poll_drains_without_waiting() {
        let q = Arc::new(FairQueue::new(16));
        let b = Batcher::new(Arc::clone(&q), BatchPolicy::default());
        // Empty queue: returns immediately with nothing.
        let t0 = Instant::now();
        assert!(b.poll(4).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50), "poll must not block");
        assert!(b.poll(0).is_empty());
        // Queued requests come back, capped at the free-slot count.
        for i in 0..5 {
            q.try_push(req(i)).unwrap();
        }
        assert_eq!(b.poll(3).len(), 3);
        assert_eq!(b.poll(8).len(), 2);
    }

    #[test]
    fn closed_queue_returns_none_after_drain() {
        let q = Arc::new(FairQueue::new(4));
        q.try_push(req(1)).unwrap();
        q.close();
        let b = Batcher::new(Arc::clone(&q), BatchPolicy::default());
        assert_eq!(b.next_batch(Duration::from_millis(10)).unwrap().requests.len(), 1);
        assert!(b.next_batch(Duration::from_millis(10)).is_none());
    }
}
