//! First-class blocking client for the wire protocol (v2).
//!
//! Entry point is [`Client::prompt`], which returns a
//! [`RequestBuilder`]:
//!
//! ```no_run
//! # use rsr::serving::client::Client;
//! # fn main() -> rsr::error::Result<()> {
//! let mut client = Client::connect("127.0.0.1:7777".parse().unwrap())?;
//! let out = client.prompt(1, "hello").max_new(8).deadline_ms(2_000).send()?;
//! if let Some((code, msg)) = &out.error {
//!     eprintln!("failed ({code:?}): {msg}");
//! } else {
//!     println!("{}", out.text);
//! }
//! // Streaming: one callback per token frame, then the terminal outcome.
//! let out = client
//!     .prompt(2, "hello again")
//!     .max_new(8)
//!     .stream(true)
//!     .stream_with(|frame| {
//!         if let Some(text) = frame.get("text").and_then(|t| t.as_str()) {
//!             print!("{text}");
//!         }
//!     })?;
//! assert!(out.is_ok());
//! # Ok(())
//! # }
//! ```
//!
//! Terminal failures surface as machine-readable [`ErrorCode`]s parsed
//! from the wire `code` field — callers branch on the enum, never on
//! error prose (the prose is for humans and carries no stability
//! promise; see ARCHITECTURE.md §Wire protocol v2 for the code table).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Stable machine-readable terminal outcome codes — the wire `code`
/// field. One variant per code the server emits, plus [`Other`] for
/// forward compatibility with codes this client version predates.
///
/// [`Other`]: ErrorCode::Other
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed or out-of-range request (`bad_request`).
    BadRequest,
    /// Admission queue at capacity — retry later (`queue_full`).
    QueueFull,
    /// Server is draining and refuses new work (`draining`).
    Draining,
    /// Request deadline expired (`deadline_exceeded`).
    DeadlineExceeded,
    /// Cancelled — typically a client disconnect (`cancelled`).
    Cancelled,
    /// KV memory budget exhausted under load (`kv_budget_exceeded`).
    KvBudgetExceeded,
    /// Replicas stalled, saturated or shut down (`unavailable`).
    Unavailable,
    /// Server-side fault: worker panic, dispatcher loss (`internal`).
    Internal,
    /// A code this client version doesn't know.
    Other,
}

impl ErrorCode {
    /// Parse a wire `code` string.
    pub fn from_wire(code: &str) -> Self {
        match code {
            "bad_request" => Self::BadRequest,
            "queue_full" => Self::QueueFull,
            "draining" => Self::Draining,
            "deadline_exceeded" => Self::DeadlineExceeded,
            "cancelled" => Self::Cancelled,
            "kv_budget_exceeded" => Self::KvBudgetExceeded,
            "unavailable" => Self::Unavailable,
            "internal" => Self::Internal,
            _ => Self::Other,
        }
    }
}

/// Parsed terminal reply: the v1 response line or the v2 `done` frame.
#[derive(Debug)]
pub struct Outcome {
    /// Client-assigned request id echoed by the server.
    pub id: u64,
    /// Decoded completion text (empty on error).
    pub text: String,
    /// Generated token ids (empty on error).
    pub tokens: Vec<u32>,
    /// Terminal failure: machine-readable code + human prose.
    pub error: Option<(ErrorCode, String)>,
    /// The raw reply object (timings and any fields this struct
    /// doesn't model).
    pub raw: Json,
}

impl Outcome {
    /// True when the request completed (no terminal error).
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// The terminal error code, when the request failed.
    pub fn code(&self) -> Option<ErrorCode> {
        self.error.as_ref().map(|(c, _)| *c)
    }

    fn from_json(raw: Json) -> Self {
        let id = raw.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let error = raw.get("error").and_then(|e| e.as_str()).map(|msg| {
            let code = raw
                .get("code")
                .and_then(|c| c.as_str())
                .map(ErrorCode::from_wire)
                // Pre-v2 servers send no code; treat as internal.
                .unwrap_or(ErrorCode::Internal);
            (code, msg.to_string())
        });
        let text = raw
            .get("text")
            .and_then(|t| t.as_str())
            .unwrap_or_default()
            .to_string();
        let tokens = match raw.get("tokens") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|x| x as u32)
                .collect(),
            _ => Vec::new(),
        };
        Self { id, text, tokens, error, raw }
    }
}

/// A minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
}

/// One request under construction — build with [`Client::prompt`],
/// finish with [`send`](RequestBuilder::send) /
/// [`send_json`](RequestBuilder::send_json) /
/// [`stream_with`](RequestBuilder::stream_with).
pub struct RequestBuilder<'c> {
    client: &'c mut Client,
    id: u64,
    prompt: String,
    max_new: usize,
    deadline_ms: Option<u64>,
    stream: bool,
}

impl Client {
    /// Connect to a server.
    pub fn connect(addr: std::net::SocketAddr) -> Result<Self> {
        Ok(Self { stream: TcpStream::connect(addr)? })
    }

    /// Start building a request (default `max_new` 16, no deadline,
    /// not streamed).
    pub fn prompt(&mut self, id: u64, prompt: &str) -> RequestBuilder<'_> {
        RequestBuilder {
            client: self,
            id,
            prompt: prompt.to_string(),
            max_new: 16,
            deadline_ms: None,
            stream: false,
        }
    }

    /// Send a control command (`metrics` / `status` / `trace` /
    /// `drain`) and return the reply object.
    pub fn control(&mut self, cmd: &str) -> Result<Json> {
        let line = Json::obj(vec![("cmd", Json::str(cmd))]);
        self.send_raw(&line.to_string())
    }

    /// Send a raw line (failure-injection tests) and read one reply
    /// line.
    pub fn send_raw(&mut self, line: &str) -> Result<Json> {
        writeln!(self.stream, "{line}")?;
        self.read_reply()
    }

    /// Send one prompt and wait for the reply line.
    #[deprecated(note = "use `client.prompt(id, text).max_new(n).send_json()`")]
    pub fn request(&mut self, id: u64, prompt: &str, max_new: usize) -> Result<Json> {
        self.prompt(id, prompt).max_new(max_new).send_json()
    }

    /// Send one prompt with an optional per-request deadline.
    #[deprecated(
        note = "use `client.prompt(id, text).max_new(n).deadline_ms(ms).send_json()`"
    )]
    pub fn request_with(
        &mut self,
        id: u64,
        prompt: &str,
        max_new: usize,
        deadline_ms: Option<u64>,
    ) -> Result<Json> {
        let mut b = self.prompt(id, prompt).max_new(max_new);
        if let Some(ms) = deadline_ms {
            b = b.deadline_ms(ms);
        }
        b.send_json()
    }

    fn read_reply(&mut self) -> Result<Json> {
        let mut reader = BufReader::new(self.stream.try_clone()?);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(Error::Unavailable("server closed the connection".into()));
        }
        Json::parse(&line).map_err(Error::Serving)
    }
}

impl RequestBuilder<'_> {
    /// Generation budget in tokens (1..=4096; default 16).
    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n;
        self
    }

    /// Total request budget in milliseconds — the server sheds or
    /// retires the request with code `deadline_exceeded` once it
    /// expires.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Request incremental token frames instead of one reply line.
    /// Read them with [`stream_with`](Self::stream_with);
    /// [`send`](Self::send) / [`send_json`](Self::send_json) also
    /// accept a streamed reply by skipping to the `done` frame.
    pub fn stream(mut self, on: bool) -> Self {
        self.stream = on;
        self
    }

    fn wire_line(&self) -> String {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("prompt", Json::str(self.prompt.clone())),
            ("max_new", Json::num(self.max_new as f64)),
        ];
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", Json::num(ms as f64)));
        }
        if self.stream {
            fields.push(("stream", Json::Bool(true)));
        }
        Json::obj(fields).to_string()
    }

    /// Send and return the raw terminal reply object (the v1 line, or
    /// the `done` frame of a streamed request — intermediate token
    /// frames are read and discarded).
    pub fn send_json(self) -> Result<Json> {
        self.stream_frames(|_| {})
    }

    /// Send and return the typed terminal [`Outcome`].
    pub fn send(self) -> Result<Outcome> {
        self.send_json().map(Outcome::from_json)
    }

    /// Send a streaming request, invoking `on_frame` with each raw
    /// token frame (fields `event`/`id`/`index`/`token`/`text`; the
    /// flush frame carries `text` only) as it arrives, and return the
    /// typed terminal [`Outcome`] of the `done` frame. Implies
    /// [`stream(true)`](Self::stream).
    pub fn stream_with(mut self, on_frame: impl FnMut(&Json)) -> Result<Outcome> {
        self.stream = true;
        self.stream_frames(on_frame).map(Outcome::from_json)
    }

    /// Shared wire loop: write the request line, forward token frames
    /// to `on_frame`, return the terminal reply.
    fn stream_frames(self, mut on_frame: impl FnMut(&Json)) -> Result<Json> {
        let line = self.wire_line();
        writeln!(self.client.stream, "{line}")?;
        let mut reader = BufReader::new(self.client.stream.try_clone()?);
        let mut buf = String::new();
        loop {
            buf.clear();
            reader.read_line(&mut buf)?;
            if buf.is_empty() {
                return Err(Error::Unavailable("server closed the connection".into()));
            }
            let json = Json::parse(&buf).map_err(Error::Serving)?;
            match json.get("event").and_then(|e| e.as_str()) {
                Some("token") => on_frame(&json),
                // "done", or a v1-shaped line (no event field at all).
                _ => return Ok(json),
            }
        }
    }
}
