//! Admission queues — the backpressure point of the serving stack.
//!
//! [`BoundedQueue`] is a bounded MPMC FIFO with blocking pop and
//! non-blocking push: when the queue is full, `try_push` fails and the
//! server returns an overload error instead of accepting unbounded
//! work.
//!
//! [`FairQueue`] is the engine's admission queue since protocol v2: a
//! per-client weighted round-robin over [`Request`] lanes keyed by
//! [`Request::client`] under one bounded global cap. Within a lane,
//! order is FIFO; across lanes, pops rotate so a chatty client's
//! backlog cannot starve others. With a single lane (all requests from
//! one client, or every `client == 0`) it degenerates to exactly the
//! old FIFO.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::request::Request;

/// Bounded FIFO queue shared between producers (server threads) and
/// consumers (engine workers).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a push was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity — caller should shed load.
    Full,
    /// Queue closed — system shutting down.
    Closed,
}

impl<T> BoundedQueue<T> {
    /// Queue with the given capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push; fails when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout; `None` on timeout or when closed and
    /// drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let (guard, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                return None;
            }
        }
    }

    /// Drain up to `max` items without blocking (after at least one is
    /// available) — the batcher's bulk pickup.
    pub fn pop_many(&self, max: usize, timeout: Duration) -> Vec<T> {
        let mut out = Vec::new();
        if let Some(first) = self.pop_timeout(timeout) {
            out.push(first);
            let mut g = self.inner.lock().unwrap();
            while out.len() < max {
                match g.items.pop_front() {
                    Some(x) => out.push(x),
                    None => break,
                }
            }
        }
        out
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// True when closed.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

/// Bounded per-client weighted round-robin admission queue.
///
/// Requests land in per-client FIFO lanes (keyed by
/// [`Request::client`]); consumers pop lanes in round-robin rotation,
/// taking up to `weight` requests from a lane before moving on
/// (`weight == 1`, the default, is classic fair round-robin). The
/// capacity bounds the **global** item count — the shed decision is
/// identical to [`BoundedQueue`]'s, so conservation semantics carry
/// over unchanged.
pub struct FairQueue {
    inner: Mutex<FairInner>,
    not_empty: Condvar,
    capacity: usize,
    /// Requests served from one lane per rotation turn.
    weight: usize,
}

#[derive(Default)]
struct FairInner {
    /// Per-client FIFO lanes. A lane exists iff it holds ≥ 1 request.
    lanes: HashMap<u64, VecDeque<Request>>,
    /// Round-robin rotation of lane keys; front is served next. Every
    /// non-empty lane appears exactly once.
    order: VecDeque<u64>,
    /// Remaining turn budget of the front lane (starts at `weight`).
    turn_left: usize,
    /// Total queued requests across lanes.
    len: usize,
    closed: bool,
}

impl FairInner {
    /// Pop the next request in weighted round-robin order.
    fn pop(&mut self, weight: usize) -> Option<Request> {
        let &key = self.order.front()?;
        if self.turn_left == 0 {
            self.turn_left = weight;
        }
        let lane = self.lanes.get_mut(&key).expect("lane in rotation exists");
        let item = lane.pop_front().expect("lane in rotation is non-empty");
        self.len -= 1;
        self.turn_left -= 1;
        if lane.is_empty() {
            self.lanes.remove(&key);
            self.order.pop_front();
            self.turn_left = 0;
        } else if self.turn_left == 0 {
            // Turn spent: rotate the lane to the back.
            self.order.rotate_left(1);
        }
        Some(item)
    }
}

impl FairQueue {
    /// Queue with the given global capacity (≥ 1) and unit lane weight.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(FairInner::default()),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
            weight: 1,
        }
    }

    /// Serve up to `weight` requests per lane per rotation turn (≥ 1).
    pub fn with_weight(mut self, weight: usize) -> Self {
        self.weight = weight.max(1);
        self
    }

    /// Non-blocking push into the sender's lane; fails when the global
    /// cap is reached or the queue is closed.
    pub fn try_push(&self, item: Request) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.len >= self.capacity {
            return Err(PushError::Full);
        }
        let key = item.client;
        let lane = g.lanes.entry(key).or_default();
        let was_empty = lane.is_empty();
        lane.push_back(item);
        g.len += 1;
        if was_empty {
            g.order.push_back(key);
        }
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking round-robin pop with timeout; `None` on timeout or
    /// when closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Request> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.pop(self.weight) {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let (guard, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = guard;
            if res.timed_out() && g.len == 0 {
                return None;
            }
        }
    }

    /// Drain up to `max` requests in rotation order without blocking
    /// (after at least one is available) — the batcher's bulk pickup.
    pub fn pop_many(&self, max: usize, timeout: Duration) -> Vec<Request> {
        let mut out = Vec::new();
        if let Some(first) = self.pop_timeout(timeout) {
            out.push(first);
            let mut g = self.inner.lock().unwrap();
            while out.len() < max {
                match g.pop(self.weight) {
                    Some(x) => out.push(x),
                    None => break,
                }
            }
        }
        out
    }

    /// Requests currently queued across all lanes.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// True when closed.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(2));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn backpressure_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full));
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        q.try_push(3).unwrap();
    }

    #[test]
    fn close_rejects_producers_but_drains() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2), Err(PushError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn pop_many_batches() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let batch = q.pop_many(3, Duration::from_millis(10));
        assert_eq!(batch, vec![0, 1, 2]);
        let rest = q.pop_many(10, Duration::from_millis(10));
        assert_eq!(rest, vec![3, 4]);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(100));
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                while qp.try_push(i).is_err() {
                    std::thread::yield_now();
                }
            }
            qp.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop_timeout(Duration::from_secs(5)) {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    // ------------------------------------------------------------ //
    // FairQueue                                                     //
    // ------------------------------------------------------------ //

    fn req(id: u64, client: u64) -> Request {
        Request::new(id, vec![1], 4).with_client(client)
    }

    fn drain_ids(q: &FairQueue) -> Vec<u64> {
        let mut ids = Vec::new();
        while let Some(r) = q.pop_timeout(Duration::from_millis(1)) {
            ids.push(r.id);
        }
        ids
    }

    #[test]
    fn single_lane_degenerates_to_fifo() {
        let q = FairQueue::new(10);
        for i in 0..4 {
            q.try_push(req(i, 0)).unwrap();
        }
        assert_eq!(drain_ids(&q), vec![0, 1, 2, 3]);
    }

    #[test]
    fn round_robin_interleaves_a_chatty_client_with_others() {
        let q = FairQueue::new(16);
        // Client 1 floods 6 requests before clients 2 and 3 get one in.
        for i in 0..6 {
            q.try_push(req(10 + i, 1)).unwrap();
        }
        q.try_push(req(20, 2)).unwrap();
        q.try_push(req(30, 3)).unwrap();
        // Rotation: lanes entered the rotation in order 1, 2, 3, so
        // the late clients' single requests are served on the first
        // rotation turns — not behind the 6-deep backlog.
        assert_eq!(drain_ids(&q), vec![10, 20, 30, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn weight_serves_bursts_per_turn() {
        let q = FairQueue::new(16).with_weight(2);
        for i in 0..4 {
            q.try_push(req(10 + i, 1)).unwrap();
        }
        q.try_push(req(20, 2)).unwrap();
        q.try_push(req(21, 2)).unwrap();
        q.try_push(req(22, 2)).unwrap();
        // Two per lane per turn.
        assert_eq!(drain_ids(&q), vec![10, 11, 20, 21, 12, 13, 22]);
    }

    #[test]
    fn global_cap_sheds_regardless_of_lane() {
        let q = FairQueue::new(2);
        q.try_push(req(1, 1)).unwrap();
        q.try_push(req(2, 2)).unwrap();
        assert_eq!(q.try_push(req(3, 3)).unwrap_err(), PushError::Full);
        assert_eq!(q.len(), 2);
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        q.try_push(req(3, 3)).unwrap();
    }

    #[test]
    fn fair_close_rejects_producers_but_drains() {
        let q = FairQueue::new(4);
        q.try_push(req(1, 1)).unwrap();
        q.close();
        assert_eq!(q.try_push(req(2, 1)).unwrap_err(), PushError::Closed);
        assert_eq!(drain_ids(&q), vec![1]);
        assert!(q.is_closed());
    }

    #[test]
    fn fair_pop_many_respects_rotation() {
        let q = FairQueue::new(16);
        for i in 0..3 {
            q.try_push(req(10 + i, 1)).unwrap();
        }
        q.try_push(req(20, 2)).unwrap();
        let batch = q.pop_many(3, Duration::from_millis(5));
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 20, 11]);
        assert_eq!(q.len(), 1);
    }
}
