//! A bounded MPMC queue with blocking pop and non-blocking push —
//! the backpressure point of the serving stack: when the queue is
//! full, `try_push` fails and the server returns an overload error
//! instead of accepting unbounded work.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Bounded FIFO queue shared between producers (server threads) and
/// consumers (engine workers).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a push was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity — caller should shed load.
    Full,
    /// Queue closed — system shutting down.
    Closed,
}

impl<T> BoundedQueue<T> {
    /// Queue with the given capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking push; fails when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout; `None` on timeout or when closed and
    /// drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let (guard, res) = self.not_empty.wait_timeout(g, timeout).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                return None;
            }
        }
    }

    /// Drain up to `max` items without blocking (after at least one is
    /// available) — the batcher's bulk pickup.
    pub fn pop_many(&self, max: usize, timeout: Duration) -> Vec<T> {
        let mut out = Vec::new();
        if let Some(first) = self.pop_timeout(timeout) {
            out.push(first);
            let mut g = self.inner.lock().unwrap();
            while out.len() < max {
                match g.items.pop_front() {
                    Some(x) => out.push(x),
                    None => break,
                }
            }
        }
        out
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    /// True when closed.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(2));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn backpressure_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full));
        q.pop_timeout(Duration::from_millis(1)).unwrap();
        q.try_push(3).unwrap();
    }

    #[test]
    fn close_rejects_producers_but_drains() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.close();
        assert_eq!(q.try_push(2), Err(PushError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn pop_many_batches() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let batch = q.pop_many(3, Duration::from_millis(10));
        assert_eq!(batch, vec![0, 1, 2]);
        let rest = q.pop_many(10, Duration::from_millis(10));
        assert_eq!(rest, vec![3, 4]);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(100));
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..1000 {
                while qp.try_push(i).is_err() {
                    std::thread::yield_now();
                }
            }
            qp.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop_timeout(Duration::from_secs(5)) {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }
}
