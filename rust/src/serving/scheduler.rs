//! Prefill/decode-aware scheduling.
//!
//! Each engine worker runs one sequence at a time (batch-1 vector
//! matmuls — the paper's setting), so the scheduler's job is admission
//! *order*: short-prompt requests (cheap prefill) are admitted ahead of
//! long-prompt ones within a batch window, bounding head-of-line
//! blocking, while an aging bound prevents starvation.

use std::time::Duration;

use super::request::Request;

/// Scheduling policy for ordering admitted requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Arrival order.
    Fifo,
    /// Shortest prompt first within the window, with aging: anything
    /// older than the bound goes first regardless of length.
    ShortestPromptFirst {
        /// Aging bound; older requests jump the length ordering.
        aging: Duration,
    },
}

impl Default for Policy {
    fn default() -> Self {
        Policy::ShortestPromptFirst { aging: Duration::from_millis(50) }
    }
}

/// Order a batch of requests for execution according to the policy.
/// Returns the same requests, re-ordered.
pub fn schedule(mut requests: Vec<Request>, policy: Policy) -> Vec<Request> {
    match policy {
        Policy::Fifo => requests,
        Policy::ShortestPromptFirst { aging } => {
            requests.sort_by_key(|r| {
                let aged = r.arrival.elapsed() >= aging;
                // Aged requests sort before everything (key 0), the
                // rest by prompt length.
                (!aged as usize, if aged { 0 } else { r.prompt.len() })
            });
            requests
        }
    }
}

/// Decode-work estimate for a request: prefill cost ≈ prompt length,
/// decode cost ≈ max_new_tokens; used by the router's load accounting.
pub fn work_estimate(r: &Request) -> usize {
    r.prompt.len() + r.max_new_tokens
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, prompt_len: usize) -> Request {
        Request::new(id, vec![0; prompt_len], 8)
    }

    #[test]
    fn fifo_preserves_order() {
        let rs = vec![req(1, 10), req(2, 1), req(3, 5)];
        let out = schedule(rs, Policy::Fifo);
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn shortest_prompt_first() {
        let rs = vec![req(1, 10), req(2, 1), req(3, 5)];
        let out =
            schedule(rs, Policy::ShortestPromptFirst { aging: Duration::from_secs(60) });
        let ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn aged_requests_jump_the_queue() {
        let mut old = req(1, 100);
        old.arrival = Instant::now() - Duration::from_secs(1);
        let rs = vec![req(2, 1), old, req(3, 2)];
        let out =
            schedule(rs, Policy::ShortestPromptFirst { aging: Duration::from_millis(10) });
        assert_eq!(out[0].id, 1, "aged request must be first");
    }

    #[test]
    fn work_estimate_sums_phases() {
        assert_eq!(work_estimate(&req(1, 7)), 15);
    }
}
