//! Least-loaded routing across engines.
//!
//! An engine is one model replica (its own workers and queue). The
//! router picks the replica with the smallest load signal
//! (queue depth + inflight), falling back through replicas when the
//! preferred one is saturated — the same strategy vllm-project/router
//! uses across model servers.

use std::sync::Arc;

use super::engine::InferenceEngine;
use super::request::Request;
use crate::error::{Error, Result};

/// Routes requests across replicas.
pub struct Router {
    engines: Vec<Arc<InferenceEngine>>,
}

impl Router {
    /// Router over ≥ 1 replicas.
    pub fn new(engines: Vec<Arc<InferenceEngine>>) -> Result<Self> {
        if engines.is_empty() {
            return Err(Error::Config("router needs at least one engine".into()));
        }
        Ok(Self { engines })
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// The replica a request would currently be routed to.
    pub fn pick(&self) -> usize {
        self.engines
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.load())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Submit to the least-loaded replica, falling back through the
    /// others if it rejects (all-full → error). Requests are cheap to
    /// clone (token ids), so each attempt gets its own copy.
    pub fn submit(&self, request: Request) -> Result<usize> {
        let start = self.pick();
        let n = self.engines.len();
        let mut last_err = None;
        for off in 0..n {
            let idx = (start + off) % n;
            match self.engines[idx].submit(request.clone()) {
                Ok(()) => return Ok(idx),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Serving("all replicas saturated".into())))
    }

    /// Engine handle by index (metrics, recv).
    pub fn engine(&self, idx: usize) -> &Arc<InferenceEngine> {
        &self.engines[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::engine::EngineConfig;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;
    use std::time::Duration;

    fn engines(n: usize) -> Vec<Arc<InferenceEngine>> {
        let weights =
            Arc::new(ModelWeights::generate(ModelConfig::tiny(), 7).unwrap());
        (0..n)
            .map(|_| {
                Arc::new(
                    InferenceEngine::start(
                        Arc::clone(&weights),
                        EngineConfig { workers: 1, ..Default::default() },
                    )
                    .unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn requires_at_least_one_engine() {
        assert!(Router::new(vec![]).is_err());
    }

    #[test]
    fn routes_to_least_loaded() {
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        // Load replica 0 with work so pick() moves to 1.
        es[0].submit(Request::new(1, vec![1; 8], 4)).unwrap();
        es[0].submit(Request::new(2, vec![1; 8], 4)).unwrap();
        assert_eq!(router.pick(), 1);
        // Drain.
        for e in &es {
            while e.inflight() > 0 {
                e.recv_timeout(Duration::from_secs(30));
            }
        }
    }

    #[test]
    fn submit_spreads_requests() {
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        let mut routed = [0usize; 2];
        for i in 0..6 {
            let idx = router.submit(Request::new(i, vec![2, 3], 2)).unwrap();
            routed[idx] += 1;
        }
        assert!(routed[0] > 0 && routed[1] > 0, "routed = {routed:?}");
        for e in &es {
            while e.inflight() > 0 {
                e.recv_timeout(Duration::from_secs(30));
            }
        }
    }
}
