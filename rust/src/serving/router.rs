//! Least-loaded routing across engines, with replica health.
//!
//! An engine is one model replica (its own workers and queue). The
//! router picks the replica with the smallest load signal
//! (queue depth + inflight), falling back through replicas when the
//! preferred one is saturated — the same strategy vllm-project/router
//! uses across model servers.
//!
//! # Replica health
//!
//! Every engine exposes a heartbeat ([`InferenceEngine::heartbeat_age`]
//! — time since a worker last topped its loop or completed a step).
//! With a stall threshold configured
//! ([`with_replica_stall`](Router::with_replica_stall), the
//! `--replica-stall-ms` flag), [`pick`](Router::pick) and
//! [`submit`](Router::submit) skip replicas whose heartbeat is staler
//! than the threshold, so one wedged replica no longer blackholes its
//! share of traffic. The circuit is implicitly half-open: staleness is
//! re-evaluated per submit, so the moment a stalled replica's worker
//! beats again it rejoins the rotation — no manual reset. The
//! threshold must exceed the model's worst-case single-step time, or
//! healthy-but-slow replicas flap out of rotation.
//!
//! # Terminal errors
//!
//! [`Error::DeadlineExceeded`] and [`Error::Cancelled`] are properties
//! of the *request*, not the replica — falling back would re-shed the
//! same dead request N times (double-counting metrics along the way),
//! so the router returns them immediately.

use std::sync::Arc;
use std::time::Duration;

use super::engine::InferenceEngine;
use super::request::Request;
use crate::error::{Error, Result};

/// Routes requests across replicas.
pub struct Router {
    engines: Vec<Arc<InferenceEngine>>,
    /// Heartbeat staleness beyond which a replica is skipped. `None`
    /// disables health filtering (the pre-health behavior).
    stall: Option<Duration>,
}

impl Router {
    /// Router over ≥ 1 replicas (no health filtering).
    pub fn new(engines: Vec<Arc<InferenceEngine>>) -> Result<Self> {
        if engines.is_empty() {
            return Err(Error::Config("router needs at least one engine".into()));
        }
        Ok(Self { engines, stall: None })
    }

    /// Skip replicas whose heartbeat is staler than `threshold`
    /// (the `--replica-stall-ms` flag).
    pub fn with_replica_stall(mut self, threshold: Duration) -> Self {
        self.stall = Some(threshold);
        self
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// Whether a replica may take new traffic: heartbeat fresh enough
    /// (when a stall threshold is set) and not draining.
    fn healthy(&self, idx: usize) -> bool {
        if self.engines[idx].is_draining() {
            return false;
        }
        match self.stall {
            None => true,
            Some(t) => self.engines[idx].heartbeat_age() <= t,
        }
    }

    /// A replica's routing load: requests waiting in its admission
    /// queue plus decode slots currently seated — the signal named by
    /// the protocol-v2 front door (a replica with deep queue OR full
    /// slots is equally unattractive).
    fn load_of(e: &InferenceEngine) -> usize {
        e.queue_depth() + e.live_slots()
    }

    /// The replica a request would currently be routed to: least
    /// loaded among the healthy (fresh-heartbeat, non-draining) ones.
    /// With every replica stalled or draining this falls back to the
    /// overall least-loaded (informational — a
    /// [`submit`](Router::submit) in that state errors instead).
    pub fn pick(&self) -> usize {
        let healthy = self
            .engines
            .iter()
            .enumerate()
            .filter(|(i, _)| self.healthy(*i))
            .min_by_key(|(_, e)| Self::load_of(e))
            .map(|(i, _)| i);
        healthy.unwrap_or_else(|| {
            self.engines
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| Self::load_of(e))
                .map(|(i, _)| i)
                .unwrap()
        })
    }

    /// Submit to the least-loaded healthy replica, falling back through
    /// the other healthy ones if it rejects (all-full → error; every
    /// replica stalled → error naming the condition). Requests are
    /// cheap to clone (token ids), so each attempt gets its own copy.
    /// Deadline/cancel rejections are terminal for the *request* —
    /// they return immediately, never falling back.
    pub fn submit(&self, request: Request) -> Result<usize> {
        let start = self.pick();
        let n = self.engines.len();
        let mut tried = 0usize;
        let mut last_err = None;
        for off in 0..n {
            let idx = (start + off) % n;
            if !self.healthy(idx) {
                continue;
            }
            tried += 1;
            match self.engines[idx].submit(request.clone()) {
                Ok(()) => return Ok(idx),
                // The request is dead no matter which replica holds it.
                Err(e @ (Error::DeadlineExceeded(_) | Error::Cancelled(_))) => {
                    return Err(e);
                }
                Err(e) => last_err = Some(e),
            }
        }
        if tried == 0 {
            if self.engines.iter().all(|e| e.is_draining()) {
                return Err(Error::Draining(format!(
                    "all {n} replica(s) draining — not accepting new work"
                )));
            }
            return Err(Error::Unavailable(format!(
                "all {n} replica(s) stalled — heartbeats older than the \
                 --replica-stall-ms threshold"
            )));
        }
        Err(last_err
            .unwrap_or_else(|| Error::Unavailable("all replicas saturated".into())))
    }

    /// Engine handle by index (metrics, recv).
    pub fn engine(&self, idx: usize) -> &Arc<InferenceEngine> {
        &self.engines[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::{EngineConfig, FaultPlan};
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::ModelWeights;
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    fn engines_with(n: usize, cfgs: Vec<EngineConfig>) -> Vec<Arc<InferenceEngine>> {
        let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 7).unwrap());
        assert_eq!(cfgs.len(), n);
        cfgs.into_iter()
            .map(|cfg| {
                Arc::new(InferenceEngine::start(Arc::clone(&weights), cfg).unwrap())
            })
            .collect()
    }

    fn engines(n: usize) -> Vec<Arc<InferenceEngine>> {
        engines_with(
            n,
            (0..n).map(|_| EngineConfig { workers: 1, ..Default::default() }).collect(),
        )
    }

    #[test]
    fn requires_at_least_one_engine() {
        assert!(Router::new(vec![]).is_err());
    }

    #[test]
    fn routes_to_least_loaded() {
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        // Load replica 0 with work so pick() moves to 1.
        es[0].submit(Request::new(1, vec![1; 8], 4)).unwrap();
        es[0].submit(Request::new(2, vec![1; 8], 4)).unwrap();
        assert_eq!(router.pick(), 1);
        // Drain.
        for e in &es {
            while e.inflight() > 0 {
                e.recv_timeout(Duration::from_secs(30));
            }
        }
    }

    #[test]
    fn submit_spreads_requests() {
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        let mut routed = [0usize; 2];
        for i in 0..6 {
            let idx = router.submit(Request::new(i, vec![2, 3], 2)).unwrap();
            routed[idx] += 1;
        }
        assert!(routed[0] > 0 && routed[1] > 0, "routed = {routed:?}");
        for e in &es {
            while e.inflight() > 0 {
                e.recv_timeout(Duration::from_secs(30));
            }
        }
    }

    #[test]
    fn saturated_everywhere_names_the_condition() {
        // Both replicas forced to reject as queue-full: the router must
        // surface the backpressure error, not hang or panic.
        let cfg = || EngineConfig {
            workers: 1,
            fault: FaultPlan { force_queue_full: true, ..Default::default() },
            ..Default::default()
        };
        let es = engines_with(2, vec![cfg(), cfg()]);
        let router = Router::new(es.clone()).unwrap();
        let err = router.submit(Request::new(1, vec![2, 3], 2)).unwrap_err();
        assert!(matches!(err, Error::QueueFull(_)), "{err:?}");
        assert_eq!(err.code(), "queue_full");
        // Every replica counted the rejection; nothing was admitted.
        for e in &es {
            assert_eq!(e.metrics().rejected.load(Ordering::Relaxed), 1);
            assert_eq!(e.inflight(), 0);
        }
    }

    #[test]
    fn terminal_rejections_do_not_fall_back() {
        // A cancelled request is dead on every replica — the router
        // must return the first replica's verdict, not re-shed it N
        // times (the cancelled counter across replicas must sum to 1).
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        let req = Request::new(1, vec![2, 3], 2);
        req.cancel.cancel();
        match router.submit(req) {
            Err(Error::Cancelled(_)) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        let total: u64 =
            es.iter().map(|e| e.metrics().cancelled.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1, "terminal rejection must not cascade through replicas");
    }

    #[test]
    fn stalled_replica_is_skipped_until_heartbeat_recovers() {
        // Replica 0's worker stalls 600 ms inside its first step;
        // replica 1 stays healthy. With a 100 ms staleness threshold
        // the router must route around 0 while it is wedged, and admit
        // it back once its heartbeat resumes (implicit half-open).
        let es = engines_with(
            2,
            vec![
                EngineConfig {
                    workers: 1,
                    fault: FaultPlan {
                        stall_at_step: Some((1, 600)),
                        ..Default::default()
                    },
                    ..Default::default()
                },
                EngineConfig { workers: 1, ..Default::default() },
            ],
        );
        let router =
            Router::new(es.clone()).unwrap().with_replica_stall(Duration::from_millis(100));
        // Wedge replica 0.
        es[0].submit(Request::new(1, vec![10, 20, 30], 2)).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert!(
            es[0].heartbeat_age() > Duration::from_millis(100),
            "replica 0 must look stalled mid-step (age {:?})",
            es[0].heartbeat_age()
        );
        // Even though replica 0 has lower-or-equal load ordering, the
        // router must route to the healthy replica 1.
        assert_eq!(router.pick(), 1);
        let idx = router.submit(Request::new(2, vec![11, 21], 2)).unwrap();
        assert_eq!(idx, 1, "stalled replica must receive no new traffic");
        // Drain both replicas (replica 0's response arrives after the
        // stall completes) — after which its heartbeat is fresh again.
        for e in &es {
            while e.inflight() > 0 {
                e.recv_timeout(Duration::from_secs(30));
            }
        }
        let t0 = Instant::now();
        while es[0].heartbeat_age() > Duration::from_millis(100) {
            assert!(t0.elapsed() < Duration::from_secs(10), "heartbeat never recovered");
            std::thread::sleep(Duration::from_millis(20));
        }
        // Half-open: the recovered replica rejoins the rotation (both
        // idle → least-loaded tie resolves to index 0).
        assert_eq!(router.pick(), 0);
    }

    #[test]
    fn every_replica_stalled_is_an_error_naming_the_condition() {
        let es = engines_with(
            1,
            vec![EngineConfig {
                workers: 1,
                fault: FaultPlan { stall_at_step: Some((1, 800)), ..Default::default() },
                ..Default::default()
            }],
        );
        let router =
            Router::new(es.clone()).unwrap().with_replica_stall(Duration::from_millis(100));
        es[0].submit(Request::new(1, vec![10, 20, 30], 2)).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        let err = router.submit(Request::new(2, vec![11, 21], 2)).unwrap_err();
        // `unavailable` is the coded refusal; the prose discriminates
        // the stalled condition from plain saturation.
        assert!(matches!(err, Error::Unavailable(_)), "{err:?}");
        assert!(err.to_string().contains("stalled"), "{err}");
        // The wedged request still reaches its terminal outcome.
        while es[0].inflight() > 0 {
            es[0].recv_timeout(Duration::from_secs(30));
        }
    }

    #[test]
    fn draining_replica_receives_no_new_traffic() {
        let es = engines(2);
        let router = Router::new(es.clone()).unwrap();
        es[0].set_draining();
        for i in 0..4 {
            let idx = router.submit(Request::new(i, vec![2, 3], 2)).unwrap();
            assert_eq!(idx, 1, "draining replica must be skipped");
        }
        while es[1].inflight() > 0 {
            es[1].recv_timeout(Duration::from_secs(30));
        }
        assert_eq!(es[0].metrics().admitted.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn all_replicas_draining_is_a_coded_refusal() {
        let es = engines(1);
        let router = Router::new(es.clone()).unwrap();
        es[0].set_draining();
        let err = router.submit(Request::new(1, vec![2, 3], 2)).unwrap_err();
        assert!(matches!(err, Error::Draining(_)), "{err:?}");
        assert_eq!(err.code(), "draining");
        assert!(es[0].drained(), "idle draining replica reads drained");
    }
}
