//! Crate-wide error type.

/// Errors produced by the rsr library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// A block index failed structural validation.
    #[error("invalid index: {0}")]
    InvalidIndex(String),

    /// Shape mismatch between operands.
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),

    /// Weight / model file format problems.
    #[error("invalid model file: {0}")]
    InvalidModel(String),

    /// AOT artifact problems (missing file, bad manifest).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Serving-layer failures (queue overflow, closed channels…).
    #[error("serving error: {0}")]
    Serving(String),

    /// Configuration / CLI problems.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying I/O failure.
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Failure inside the XLA/PJRT runtime.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
