//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline registry has no
//! `thiserror`); semantics match the usual derive — `Io` is transparent
//! and carries its source.

use std::fmt;

/// Errors produced by the rsr library.
#[derive(Debug)]
pub enum Error {
    /// A block index failed structural validation.
    InvalidIndex(String),

    /// Shape mismatch between operands.
    ShapeMismatch(String),

    /// Weight / model file format problems.
    InvalidModel(String),

    /// AOT / plan artifact problems (missing file, bad manifest, bad
    /// header, checksum or version mismatch).
    Artifact(String),

    /// Serving-layer failures (closed channels, internal faults…).
    Serving(String),

    /// The admission queue is at capacity — a backpressure shed. The
    /// caller may retry; distinct from [`Serving`](Error::Serving) so
    /// clients can discriminate overload from internal failure.
    QueueFull(String),

    /// The server (or replica) is draining: it completes in-flight and
    /// queued work but refuses new submissions. Terminal for the
    /// submission — the client should go elsewhere.
    Draining(String),

    /// No replica can take the request right now (all stalled, engine
    /// shut down, or the response path is gone).
    Unavailable(String),

    /// A malformed request on the wire (bad JSON, missing or
    /// out-of-range fields).
    BadRequest(String),

    /// A request's deadline expired before it completed. Distinct from
    /// [`Serving`](Error::Serving) so the router does not fall back
    /// through replicas on a request that is already dead.
    DeadlineExceeded(String),

    /// A request was cancelled (client disconnect). Terminal — never
    /// retried or re-routed.
    Cancelled(String),

    /// The KV page pool's byte budget (`--kv-budget`) could not cover
    /// the request: shed at admission (no reservation) or evicted
    /// mid-decode (youngest-first under page exhaustion). Terminal and
    /// named — never a panic, never a silent drop.
    KvBudgetExceeded(String),

    /// Configuration / CLI problems.
    Config(String),

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// Failure inside the XLA/PJRT runtime.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidIndex(m) => write!(f, "invalid index: {m}"),
            Error::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidModel(m) => write!(f, "invalid model file: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Serving(m) => write!(f, "serving error: {m}"),
            Error::QueueFull(m) => write!(f, "queue full: {m}"),
            Error::Draining(m) => write!(f, "draining: {m}"),
            Error::Unavailable(m) => write!(f, "unavailable: {m}"),
            Error::BadRequest(m) => write!(f, "bad request: {m}"),
            Error::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
            Error::KvBudgetExceeded(m) => write!(f, "kv budget exceeded: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl Error {
    /// Stable machine-readable wire code for this error.
    ///
    /// These strings are the protocol-v2 `code` field of every error
    /// reply and are part of the wire contract — they never change
    /// once shipped (see ARCHITECTURE.md §Wire protocol v2 for the
    /// full table). Everything without a dedicated code maps to
    /// `"internal"`.
    pub fn code(&self) -> &'static str {
        match self {
            Error::BadRequest(_) => "bad_request",
            Error::QueueFull(_) => "queue_full",
            Error::Draining(_) => "draining",
            Error::DeadlineExceeded(_) => "deadline_exceeded",
            Error::Cancelled(_) => "cancelled",
            Error::KvBudgetExceeded(_) => "kv_budget_exceeded",
            Error::Unavailable(_) => "unavailable",
            _ => "internal",
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
