//! `cargo bench --bench ablations` — design-choice ablations (DESIGN.md §5).
fn main() {
    rsr::bench::experiments::ablations::run(rsr::bench::full_mode());
}
