//! `cargo bench --bench fig5_memory` — regenerates paper Fig 5.
fn main() {
    rsr::bench::experiments::fig5::run(rsr::bench::full_mode());
}
