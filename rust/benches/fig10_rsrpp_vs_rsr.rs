//! `cargo bench --bench fig10_rsrpp_vs_rsr` — regenerates paper Fig 10 / App F.2.
fn main() {
    rsr::bench::experiments::fig10::run(rsr::bench::full_mode());
}
