//! `cargo bench --bench fig6_llm_cpu` — regenerates paper Fig 6.
fn main() {
    rsr::bench::experiments::fig6::run(rsr::bench::full_mode());
}
