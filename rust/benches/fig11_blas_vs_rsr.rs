//! `cargo bench --bench fig11_blas_vs_rsr` — regenerates paper Fig 11 / App F.3.
fn main() {
    rsr::bench::experiments::fig11::run(rsr::bench::full_mode());
}
