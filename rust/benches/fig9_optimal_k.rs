//! `cargo bench --bench fig9_optimal_k` — regenerates paper Fig 9 / App F.1.
fn main() {
    rsr::bench::experiments::fig9::run(rsr::bench::full_mode());
}
