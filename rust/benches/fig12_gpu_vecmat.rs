//! `cargo bench --bench fig12_gpu_vecmat` — regenerates paper Fig 12 / App F.4.
fn main() {
    rsr::bench::experiments::fig12::run(rsr::bench::full_mode());
}
