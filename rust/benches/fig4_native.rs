//! `cargo bench --bench fig4_native` — regenerates paper Fig 4.
fn main() {
    rsr::bench::experiments::fig4::run(rsr::bench::full_mode());
}
