//! `cargo bench --bench table1_llm_gpu` — regenerates paper Table 1.
fn main() {
    rsr::bench::experiments::table1::run(rsr::bench::full_mode());
}
