//! `cargo bench --bench plan_store` — the compile-once/serve-many
//! economics this repo's serving stack is built on.
//!
//! Compares three ways a 4-worker engine can obtain its plans:
//!
//! 1. **per-worker preprocess** (the seed behavior): every worker runs
//!    Algorithm 1 itself — W× the startup latency and W index copies;
//! 2. **shared `PlanStore`**: Algorithm 1 runs once, workers share the
//!    `Arc`'d index and hold only per-thread scratch;
//! 3. **`.rsrz` artifact load**: Algorithm 1 ran offline (`rsr pack`);
//!    serving start is a checksum-verified deserialize.
//!
//! Per-call matvec latency is reported for the owned and shared paths
//! to show the sharing refactor costs nothing at request time.

use std::sync::Arc;

use rsr::bench::harness::{measure, ms, Table};
use rsr::kernels::artifact::{ArtifactPayload, PlanArtifact};
use rsr::kernels::index::TernaryRsrIndex;
use rsr::kernels::optimal_k::optimal_k_rsrpp;
use rsr::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use rsr::kernels::TernaryMatrix;
use rsr::runtime::{PlanStore, SharedTernaryPlan};
use rsr::util::rng::Rng;

fn main() {
    let full = rsr::bench::full_mode();
    let n: usize = if full { 4096 } else { 2048 };
    let workers = 4usize;
    let k = optimal_k_rsrpp(n);
    let mut rng = Rng::new(0x9A7);
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    let mut out = vec![0.0f32; n];

    let mut table =
        Table::new(&["path", "startup cost", "per-call matvec", "index copies"]);

    // 1. Seed path: every worker preprocesses its own plan.
    let m_cold = measure(format!("preprocess x{workers}"), 0, 2, || {
        let mut plans = Vec::with_capacity(workers);
        for _ in 0..workers {
            plans.push(
                TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap(),
            );
        }
        plans
    });
    let mut owned =
        TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap();
    let m_owned_exec =
        measure("owned execute", 2, 20, || owned.execute(&v, &mut out).unwrap());
    table.row(&[
        "per-worker preprocess (seed)".into(),
        ms(&m_cold),
        ms(&m_owned_exec),
        format!("{workers}"),
    ]);

    // 2. PlanStore: preprocess once, share the index, per-worker scratch.
    let m_store = measure("store build + scratches", 0, 2, || {
        let store = PlanStore::new();
        store
            .insert_ternary("w", TernaryRsrIndex::preprocess(&a, k), k, 1.0)
            .unwrap();
        let plan = store.get("w").unwrap().ternary().unwrap();
        let scratches: Vec<_> = (0..workers).map(|_| plan.scratch()).collect();
        (plan, scratches)
    });
    let shared =
        Arc::new(SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap());
    let mut scratch = shared.scratch();
    let m_shared_exec = measure("shared execute", 2, 20, || {
        shared.execute(&mut scratch, &v, &mut out).unwrap()
    });
    table.row(&[
        "shared PlanStore".into(),
        ms(&m_store),
        ms(&m_shared_exec),
        "1".into(),
    ]);

    // 3. Packed artifact: Algorithm 1 ran offline; startup is a
    //    checksum-verified load.
    let dir = std::env::temp_dir().join(format!("rsr-plan-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.rsrz");
    PlanArtifact::ternary("w", TernaryRsrIndex::preprocess(&a, k), 1.0)
        .unwrap()
        .save(&path)
        .unwrap();
    let m_load = measure("artifact load", 1, 3, || {
        let art = PlanArtifact::load(&path).unwrap();
        match art.payload {
            // v2 payload is already the flat execution form: wrap, no copy.
            ArtifactPayload::Ternary(t) => SharedTernaryPlan::from_flat(t).unwrap(),
            _ => unreachable!(),
        }
    });
    table.row(&[
        ".rsrz artifact load".into(),
        ms(&m_load),
        ms(&m_shared_exec),
        "1".into(),
    ]);

    table.print(&format!(
        "compile-once/serve-many (ternary {n}x{n}, k={k}, {workers} workers)"
    ));
    let meta = PlanArtifact::peek(&path).unwrap();
    println!(
        "\nartifact on disk: {:.2} MB vs {:.2} MB dense f32 (ratio {:.3}); \
         shared index in memory: {:.2} MB once per process instead of {workers}x",
        meta.payload_bytes as f64 / 1048576.0,
        meta.dense_f32_bytes() as f64 / 1048576.0,
        meta.ratio_vs_dense(),
        shared.index_bytes() as f64 / 1048576.0,
    );
    let _ = std::fs::remove_dir_all(&dir);
}
