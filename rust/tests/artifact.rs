//! Integration: the compile-once/serve-many contract.
//!
//! Pack (serialize) → load (deserialize) → execute must be
//! **bit-identical** to a freshly preprocessed in-memory plan, corrupt
//! or mismatched artifacts must be rejected, and the on-disk index must
//! actually be small (≤ dense-f32/4 at `n ≥ 1024` — the `rsr inspect`
//! acceptance bar).

use std::path::PathBuf;
use std::sync::Arc;

use rsr::kernels::artifact::{ternary_fingerprint, ArtifactPayload, PlanArtifact, RSRZ_VERSION};
use rsr::kernels::flat::{FlatPlan, TernaryFlatPlan};
use rsr::kernels::index::{RsrIndex, TernaryRsrIndex};
use rsr::kernels::optimal_k::optimal_k_rsrpp;
use rsr::kernels::rsrpp::TernaryRsrPlusPlusPlan;
use rsr::kernels::{BinaryMatrix, TernaryMatrix};
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::runtime::{PlanStore, SharedTernaryPlan};
use rsr::util::rng::Rng;

/// Fresh per-test temp dir (no tempfile crate offline).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rsr-artifact-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn packed_plan_executes_bit_identically_to_in_memory_plan() {
    let (n, m) = (1024usize, 1024usize);
    let k = optimal_k_rsrpp(n);
    let mut rng = Rng::new(0xA11CE);
    let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);

    // Freshly preprocessed in-memory plan (the seed's only path).
    let mut owned = TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap();
    let mut expect = vec![0.0f32; m];
    owned.execute(&v, &mut expect).unwrap();

    // Pack → store-load → execute.
    let dir = temp_dir("roundtrip");
    let art =
        PlanArtifact::ternary("layer0.wq", TernaryRsrIndex::preprocess(&a, k), 1.0).unwrap();
    art.save(dir.join("layer0.wq.rsrz")).unwrap();

    let store = PlanStore::open(&dir).unwrap();
    let entry = store.get("layer0.wq").unwrap();
    assert_eq!(entry.k, k);
    let plan = entry.ternary().unwrap();
    let mut scratch = plan.scratch();
    let mut got = vec![0.0f32; m];
    plan.execute(&mut scratch, &v, &mut got).unwrap();

    assert_eq!(got, expect, "store-loaded plan must be bit-identical");

    // The acceptance bar: on-disk index ≤ dense f32 / 4 at n = 1024.
    let meta = PlanArtifact::peek(dir.join("layer0.wq.rsrz")).unwrap();
    assert!(
        meta.payload_bytes <= meta.dense_f32_bytes() / 4,
        "index {} bytes vs dense/4 {} bytes",
        meta.payload_bytes,
        meta.dense_f32_bytes() / 4
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serialize_deserialize_preserves_index_exactly() {
    let mut rng = Rng::new(0xBEEF);
    for (n, m, k) in [(64usize, 64usize, 3usize), (100, 60, 4), (33, 7, 5)] {
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let art = PlanArtifact::ternary("t", idx.clone(), 0.5).unwrap();
        let mut buf = Vec::new();
        art.write_to(&mut buf).unwrap();
        let back = PlanArtifact::read_from(&mut buf.as_slice()).unwrap();
        let flat = TernaryFlatPlan::from_index(&idx).unwrap();
        match back.payload {
            ArtifactPayload::Ternary(got) => assert_eq!(got, flat, "n={n} m={m} k={k}"),
            _ => panic!("wrong kind"),
        }
    }
    // Binary artifacts too.
    let b = BinaryMatrix::random(80, 48, 0.5, &mut rng);
    let idx = RsrIndex::preprocess(&b, 4);
    let art = PlanArtifact::binary("b", idx.clone(), 1.0).unwrap();
    let mut buf = Vec::new();
    art.write_to(&mut buf).unwrap();
    match PlanArtifact::read_from(&mut buf.as_slice()).unwrap().payload {
        ArtifactPayload::Binary(got) => {
            assert_eq!(got, FlatPlan::from_index(&idx).unwrap());
            // The boxed index form is recoverable from the arena.
            assert_eq!(got.to_index(), idx);
        }
        _ => panic!("wrong kind"),
    }
}

#[test]
fn corrupted_header_is_rejected() {
    let mut rng = Rng::new(0xC0DE);
    let a = TernaryMatrix::random(48, 32, 1.0 / 3.0, &mut rng);
    let art = PlanArtifact::ternary("t", TernaryRsrIndex::preprocess(&a, 3), 1.0).unwrap();
    let mut buf = Vec::new();
    art.write_to(&mut buf).unwrap();

    // Magic.
    let mut bad = buf.clone();
    bad[2] ^= 0xFF;
    assert!(PlanArtifact::read_from(&mut bad.as_slice()).is_err());
    // Kind (offset 8).
    let mut bad = buf.clone();
    bad[8] = 77;
    assert!(PlanArtifact::read_from(&mut bad.as_slice()).is_err());
    // Declared rows (offset 12) no longer matches the payload geometry.
    let mut bad = buf.clone();
    bad[12] = bad[12].wrapping_add(1);
    assert!(PlanArtifact::read_from(&mut bad.as_slice()).is_err());
    // k out of range (offset 20).
    let mut bad = buf.clone();
    bad[20] = 99;
    assert!(PlanArtifact::read_from(&mut bad.as_slice()).is_err());
}

#[test]
fn version_mismatch_is_rejected() {
    let mut rng = Rng::new(0xFACE);
    let a = TernaryMatrix::random(24, 24, 1.0 / 3.0, &mut rng);
    let art = PlanArtifact::ternary("t", TernaryRsrIndex::preprocess(&a, 3), 1.0).unwrap();
    let mut buf = Vec::new();
    art.write_to(&mut buf).unwrap();
    assert_eq!(
        u32::from_le_bytes(buf[4..8].try_into().unwrap()),
        RSRZ_VERSION,
        "version field must sit at offset 4"
    );
    buf[4..8].copy_from_slice(&(RSRZ_VERSION + 1).to_le_bytes());
    let err = match PlanArtifact::read_from(&mut buf.as_slice()) {
        Err(e) => e,
        Ok(_) => panic!("future version must be rejected"),
    };
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn payload_corruption_fails_the_checksum() {
    let mut rng = Rng::new(0xD00D);
    let a = TernaryMatrix::random(40, 40, 1.0 / 3.0, &mut rng);
    let art = PlanArtifact::ternary("t", TernaryRsrIndex::preprocess(&a, 4), 1.0).unwrap();
    let mut buf = Vec::new();
    art.write_to(&mut buf).unwrap();
    // Flip one payload byte (well past the 60-byte header + name).
    let off = buf.len() - 7;
    buf[off] ^= 0x10;
    let err = match PlanArtifact::read_from(&mut buf.as_slice()) {
        Err(e) => e,
        Ok(_) => panic!("corrupt payload must be rejected"),
    };
    assert!(err.to_string().contains("checksum"), "{err}");
}

#[test]
fn whole_model_packs_and_serves_through_the_store() {
    // End-to-end over every layer of a model: pack all matrices, open a
    // dir-backed store, and check a sample of layers against fresh
    // preprocessing.
    let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 31).unwrap());
    let dir = temp_dir("model");
    for (name, m, scale) in weights.named_matrices() {
        let k = optimal_k_rsrpp(m.rows());
        PlanArtifact::ternary(name.clone(), TernaryRsrIndex::preprocess(m, k), scale)
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m))
            .save(dir.join(format!("{name}.rsrz")))
            .unwrap();
    }

    let store = PlanStore::open(&dir).unwrap();
    store.preload(&weights.matrix_names()).unwrap();
    assert_eq!(store.loaded_len(), weights.matrix_names().len());

    let mut rng = Rng::new(32);
    for name in ["layer0.wq", "layer1.down", "lm_head"] {
        let (m, scale) = weights.matrix(name).unwrap();
        let entry = store.get(name).unwrap();
        assert_eq!(entry.scale, scale);
        let plan: Arc<SharedTernaryPlan> = entry.ternary().unwrap();
        let v = rng.f32_vec(m.rows(), -1.0, 1.0);
        let k = optimal_k_rsrpp(m.rows());
        let mut owned =
            TernaryRsrPlusPlusPlan::new(TernaryRsrIndex::preprocess(m, k)).unwrap();
        let mut expect = vec![0.0f32; m.cols()];
        owned.execute(&v, &mut expect).unwrap();
        let mut scratch = plan.scratch();
        let mut got = vec![0.0f32; m.cols()];
        plan.execute(&mut scratch, &v, &mut got).unwrap();
        assert_eq!(got, expect, "{name}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_plans_from_other_weights_are_rejected() {
    use rsr::model::transformer::Transformer;

    // Pack plans from checkpoint A, then try to serve checkpoint B of
    // the SAME architecture: every shape matches, but the fingerprint
    // must catch the swap before any wrong logits are produced.
    let a = ModelWeights::generate(ModelConfig::tiny(), 71).unwrap();
    let b = ModelWeights::generate(ModelConfig::tiny(), 72).unwrap();
    let dir = temp_dir("stale");
    for (name, m, scale) in a.named_matrices() {
        let k = optimal_k_rsrpp(m.rows());
        PlanArtifact::ternary(name.clone(), TernaryRsrIndex::preprocess(m, k), scale)
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m))
            .save(dir.join(format!("{name}.rsrz")))
            .unwrap();
    }
    let store = PlanStore::open(&dir).unwrap();
    // Same weights: builds fine.
    assert!(Transformer::from_plan_store(&a, &store).is_ok());
    // Different weights, same shapes: must fail loudly.
    let err = match Transformer::from_plan_store(&b, &store) {
        Err(e) => e,
        Ok(_) => panic!("stale plans must be rejected"),
    };
    assert!(err.to_string().contains("fingerprint"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killing_a_pack_mid_write_never_leaves_a_loadable_but_corrupt_artifact() {
    // Simulate `rsr pack` dying at every dangerous point of an
    // artifact write and assert the trichotomy the atomic writer
    // guarantees: old file intact, complete new file, or a stray
    // `*.tmp` that no loader will touch.
    let mut rng = Rng::new(0x0DD);
    let a = TernaryMatrix::random(48, 32, 1.0 / 3.0, &mut rng);
    let art = PlanArtifact::ternary(
        "layer0.wq",
        TernaryRsrIndex::preprocess(&a, 3),
        1.0,
    )
    .unwrap();
    let dir = temp_dir("killmidwrite");
    let target = dir.join("layer0.wq.rsrz");
    art.save(&target).unwrap();
    let good_bytes = std::fs::read(&target).unwrap();

    // Kill case 1: the writer dies mid-stream. The target keeps its
    // old bytes, and no tmp survives.
    let err = rsr::util::atomicfile::write_atomic(&target, |w| {
        use std::io::Write;
        w.write_all(&good_bytes[..good_bytes.len() / 2])?;
        Err(rsr::error::Error::Artifact("killed mid-write".into()))
    })
    .unwrap_err();
    assert!(err.to_string().contains("killed"), "{err}");
    assert_eq!(std::fs::read(&target).unwrap(), good_bytes);
    assert!(PlanArtifact::load(&target).is_ok(), "old artifact still loads");

    // Kill case 2: the process dies between tmp-write and rename — a
    // truncated `.tmp` sits next to the finished artifact. The loader
    // refuses it BY NAME (even a byte-perfect tmp is untrustworthy),
    // and `PlanStore::open` quarantines it while serving the real one.
    let tmp = dir.join("layer0.wq.rsrz.tmp");
    std::fs::write(&tmp, &good_bytes[..good_bytes.len() / 2]).unwrap();
    let err = PlanArtifact::load(&tmp).unwrap_err();
    assert!(err.to_string().contains("in-flight temporary"), "{err}");

    let store = PlanStore::open(&dir).unwrap();
    assert!(!tmp.exists(), "open must quarantine the stray tmp");
    assert!(
        dir.join("layer0.wq.rsrz.tmp.quarantined").exists(),
        "the stray is kept for post-mortem, not deleted"
    );
    assert!(store.get("layer0.wq").is_ok(), "the finished artifact still serves");

    // Kill case 3: truncation slipping past the tmp discipline (e.g. a
    // torn copy) still fails the checksum — loadable-but-corrupt does
    // not exist.
    let torn = dir.join("torn.rsrz");
    std::fs::write(&torn, &good_bytes[..good_bytes.len() - 5]).unwrap();
    assert!(PlanArtifact::load(&torn).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_reports_missing_artifacts_cleanly() {
    let dir = temp_dir("missing");
    let store = PlanStore::open(&dir).unwrap();
    let err = match store.get("layer0.wq") {
        Err(e) => e,
        Ok(_) => panic!("missing artifact must error"),
    };
    assert!(err.to_string().contains("layer0.wq"), "{err}");
    assert!(PlanStore::open(dir.join("nonexistent-subdir")).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
