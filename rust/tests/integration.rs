//! Cross-module integration: preprocess → multiply end-to-end across
//! shapes and backends; index persistence; model build from saved
//! weights; CLI-level flows exercised through the library API.

use rsr::kernels::index::{RsrIndex, TernaryRsrIndex};
use rsr::kernels::optimal_k::{optimal_k_rsr, optimal_k_rsrpp};
use rsr::kernels::qbit::{QbitMatrix, QbitRsrPlan};
use rsr::kernels::rsr::{rsr_mul, TernaryRsrPlan};
use rsr::kernels::rsrpp::{rsrpp_mul, TernaryRsrPlusPlusPlan};
use rsr::kernels::standard::{standard_mul_binary, standard_mul_ternary};
use rsr::kernels::{Backend, BinaryMatrix, TernaryMatrix};
use rsr::model::bitlinear::BitLinear;
use rsr::model::config::ModelConfig;
use rsr::model::sampler::Sampler;
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::util::rng::Rng;

fn assert_close(a: &[f32], b: &[f32], tol: f32) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "elem {i}: {x} vs {y}"
        );
    }
}

#[test]
fn rsr_pipeline_over_many_shapes() {
    let mut rng = Rng::new(0xA0);
    for (n, m) in [(17, 3), (64, 64), (100, 129), (256, 40), (1000, 999)] {
        let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let expect = standard_mul_binary(&v, &b);
        for k in [1usize, 3, 7] {
            assert_close(&rsr_mul(&v, &b, k), &expect, 1e-3);
            assert_close(&rsrpp_mul(&v, &b, k), &expect, 1e-3);
        }
    }
}

#[test]
fn optimal_k_paths_agree_with_fixed_k() {
    let mut rng = Rng::new(0xA1);
    let n = 512;
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    let expect = standard_mul_ternary(&v, &a);
    for k in [optimal_k_rsr(n), optimal_k_rsrpp(n)] {
        let mut plan = TernaryRsrPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap();
        let mut out = vec![0.0; n];
        plan.execute(&v, &mut out).unwrap();
        assert_close(&out, &expect, 1e-3);
    }
}

#[test]
fn index_survives_disk_round_trip_and_still_multiplies() {
    let mut rng = Rng::new(0xA2);
    let b = BinaryMatrix::random(300, 200, 0.5, &mut rng);
    let v = rng.f32_vec(300, -1.0, 1.0);
    let idx = RsrIndex::preprocess(&b, 6);

    let path = std::env::temp_dir().join("rsr_it_index.rsi");
    idx.save(&path).unwrap();
    let loaded = RsrIndex::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(idx, loaded);

    let mut plan = rsr::kernels::rsr::RsrPlan::new(loaded).unwrap();
    let mut out = vec![0.0; 200];
    plan.execute(&v, &mut out).unwrap();
    assert_close(&out, &standard_mul_binary(&v, &b), 1e-3);
}

#[test]
fn model_from_saved_weights_matches_fresh_model() {
    let weights = ModelWeights::generate(ModelConfig::tiny(), 0xA3).unwrap();
    let path = std::env::temp_dir().join("rsr_it_model.rtw");
    weights.save(&path).unwrap();
    let loaded = ModelWeights::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let mut m1 = Transformer::from_weights(&weights, Backend::RsrPlusPlus, 0).unwrap();
    let mut m2 = Transformer::from_weights(&loaded, Backend::RsrPlusPlus, 0).unwrap();
    let mut rng = Rng::new(1);
    let prompt = [5u32, 10, 15];
    let a = m1.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
    let b = m2.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
    assert_eq!(a, b);
}

#[test]
fn qbit_pipeline_end_to_end() {
    let mut rng = Rng::new(0xA4);
    for q in [2u32, 3, 5] {
        let w = QbitMatrix::random(128, 96, q, &mut rng);
        let v = rng.f32_vec(128, -1.0, 1.0);
        let mut plan = QbitRsrPlan::preprocess(&w, 5).unwrap();
        let mut out = vec![0.0; 96];
        plan.execute(&v, &mut out).unwrap();
        assert_close(&out, &w.standard_mul(&v), 2e-2);
    }
}

#[test]
fn ternary_plans_agree_with_each_other() {
    let mut rng = Rng::new(0xA5);
    let n = 384;
    let a = TernaryMatrix::random(n, n, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);
    let idx = TernaryRsrIndex::preprocess(&a, 6);
    let mut p1 = TernaryRsrPlan::new(idx.clone()).unwrap();
    let mut p2 = TernaryRsrPlusPlusPlan::new(idx).unwrap();
    let (mut o1, mut o2) = (vec![0.0; n], vec![0.0; n]);
    p1.execute(&v, &mut o1).unwrap();
    p2.execute(&v, &mut o2).unwrap();
    assert_close(&o1, &o2, 1e-4);
}

#[test]
fn bitlinear_scale_applies_after_matmul() {
    let mut rng = Rng::new(0xA6);
    let a = TernaryMatrix::random(32, 16, 1.0 / 3.0, &mut rng);
    let v = rng.f32_vec(32, -1.0, 1.0);
    let mut unit = BitLinear::new(a.clone(), 1.0, Backend::Rsr, 4).unwrap();
    let mut half = BitLinear::new(a, 0.5, Backend::Rsr, 4).unwrap();
    let (mut o1, mut o2) = (vec![0.0; 16], vec![0.0; 16]);
    unit.forward(&v, &mut o1).unwrap();
    half.forward(&v, &mut o2).unwrap();
    for (a, b) in o1.iter().zip(o2.iter()) {
        assert!((a * 0.5 - b).abs() < 1e-5);
    }
}

#[test]
fn identity_matrix_multiplication() {
    // v · I = v under every backend (deterministic structure, catches
    // permutation/segment off-by-ones cleanly).
    let n = 64;
    let mut a = TernaryMatrix::zeros(n, n);
    for i in 0..n {
        a.set(i, i, 1);
    }
    let mut rng = Rng::new(0xA7);
    let v = rng.f32_vec(n, -2.0, 2.0);
    for backend in Backend::ALL {
        let mut layer = BitLinear::new(a.clone(), 1.0, backend, 4).unwrap();
        let mut out = vec![0.0; n];
        layer.forward(&v, &mut out).unwrap();
        assert_close(&out, &v, 1e-5);
    }
}

#[test]
fn negated_identity_flips_sign() {
    let n = 32;
    let mut a = TernaryMatrix::zeros(n, n);
    for i in 0..n {
        a.set(i, i, -1);
    }
    let mut rng = Rng::new(0xA8);
    let v = rng.f32_vec(n, -2.0, 2.0);
    let mut layer = BitLinear::new(a, 1.0, Backend::RsrPlusPlus, 3).unwrap();
    let mut out = vec![0.0; n];
    layer.forward(&v, &mut out).unwrap();
    let neg: Vec<f32> = v.iter().map(|x| -x).collect();
    assert_close(&out, &neg, 1e-5);
}
