//! Serving-stack integration: TCP round trips, concurrent clients,
//! failure injection (malformed requests, backpressure, oversized
//! prompts), and metrics accounting.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::batcher::BatchPolicy;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::client::Client;
use rsr::serving::request::Request;
use rsr::serving::router::Router;
use rsr::serving::server::Server;

fn tiny_weights() -> Arc<ModelWeights> {
    Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x5E21).unwrap())
}

struct TestServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(replicas: usize, workers: usize) -> Self {
        let weights = tiny_weights();
        let engines: Vec<Arc<InferenceEngine>> = (0..replicas)
            .map(|_| {
                Arc::new(
                    InferenceEngine::start(
                        Arc::clone(&weights),
                        EngineConfig {
                            workers,
                            backend: Backend::RsrPlusPlus,
                            ..Default::default()
                        },
                    )
                    .unwrap(),
                )
            })
            .collect();
        let router = Arc::new(Router::new(engines).unwrap());
        let server = Server::new(router);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
        let bound2 = Arc::clone(&bound);
        let thread = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", stop2, move |a| {
                    *bound2.lock().unwrap() = Some(a);
                })
                .unwrap();
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        Self { addr, stop, thread: Some(thread) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[test]
fn tcp_round_trip_generates_tokens() {
    let server = TestServer::start(1, 1);
    let mut client = Client::connect(server.addr).unwrap();
    let reply =
        client.prompt(7, "What is the capital of France?").max_new(4).send_json().unwrap();
    assert_eq!(reply.get("id").unwrap().as_f64(), Some(7.0));
    assert!(reply.get("error").is_none(), "{}", reply.to_string());
    let tokens = reply.get("tokens").unwrap().as_arr().unwrap();
    assert!(!tokens.is_empty() && tokens.len() <= 4);
    assert!(reply.get("decode_us").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn multiple_requests_on_one_connection() {
    let server = TestServer::start(1, 1);
    let mut client = Client::connect(server.addr).unwrap();
    for i in 0..3 {
        let reply =
            client.prompt(i, "How many continents are there?").max_new(2).send_json().unwrap();
        assert_eq!(reply.get("id").unwrap().as_f64(), Some(i as f64));
        assert!(reply.get("error").is_none());
    }
}

#[test]
fn concurrent_clients_get_their_own_answers() {
    let server = TestServer::start(1, 2);
    let addr = server.addr;
    let handles: Vec<_> = (0..4)
        .map(|ci| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                // Distinct prompts per client; ids deliberately overlap
                // across connections to prove isolation comes from the
                // hub, not the client id.
                let reply = client
                    .prompt(1, &format!("Question number {ci}?"))
                    .max_new(3)
                    .send_json()
                    .unwrap();
                assert!(reply.get("error").is_none(), "{}", reply.to_string());
                reply.get("tokens").unwrap().as_arr().unwrap().len()
            })
        })
        .collect();
    for h in handles {
        assert!(h.join().unwrap() > 0);
    }
}

#[test]
fn malformed_lines_get_error_replies_and_do_not_kill_connection() {
    let server = TestServer::start(1, 1);
    let mut client = Client::connect(server.addr).unwrap();
    // Not JSON.
    let reply = client.send_raw("this is not json").unwrap();
    assert!(reply.get("error").is_some());
    // Missing prompt.
    let reply = client.send_raw(r#"{"id": 3}"#).unwrap();
    assert!(reply.get("error").is_some());
    // Empty prompt.
    let reply = client.send_raw(r#"{"id": 3, "prompt": ""}"#).unwrap();
    assert!(reply.get("error").is_some());
    // max_new out of range.
    let reply =
        client.send_raw(r#"{"id": 3, "prompt": "hi", "max_new": 100000}"#).unwrap();
    assert!(reply.get("error").is_some());
    // Connection still serves good requests.
    let reply = client.prompt(4, "still alive?").max_new(2).send_json().unwrap();
    assert!(reply.get("error").is_none());
}

#[test]
fn engine_backpressure_is_reported() {
    let weights = tiny_weights();
    let engine = InferenceEngine::start(
        weights,
        EngineConfig {
            workers: 1,
            queue_capacity: 1,
            batch: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                max_slots: 1,
                // Pins the strictly sequential worker loop (the
                // pre-batching, pre-chunking code path).
                prefill_chunk: 1,
            },
            ..Default::default()
        },
    )
    .unwrap();
    let mut rejected = 0;
    for i in 0..30 {
        if engine.submit(Request::new(i, vec![3; 32], 8)).is_err() {
            rejected += 1;
        }
    }
    assert!(rejected > 0);
    let snap = engine.metrics().snapshot();
    assert!(snap.get("rejected").unwrap().as_f64().unwrap() as u64 >= rejected as u64);
    // Drain admitted requests before shutdown.
    while engine.inflight() > 0 {
        engine.recv_timeout(Duration::from_secs(30));
    }
    engine.shutdown();
}

#[test]
fn oversized_prompt_fails_cleanly() {
    let weights = tiny_weights();
    let max_seq = weights.config.max_seq_len;
    let engine = InferenceEngine::start(
        weights,
        EngineConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    engine.submit(Request::new(1, vec![5; max_seq + 10], 2)).unwrap();
    let resp = engine.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(resp.error.is_some(), "prompt longer than KV capacity must fail");
    // Engine survives and serves the next request.
    engine.submit(Request::new(2, vec![5, 6, 7], 2)).unwrap();
    let resp = engine.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(resp.error.is_none());
    engine.shutdown();
}

#[test]
fn replicated_router_balances_and_both_replicas_complete() {
    let server = TestServer::start(2, 1);
    let addr = server.addr;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                client.prompt(i, "Where is the Nile?").max_new(2).send_json().unwrap()
            })
        })
        .collect();
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.get("error").is_none(), "{}", reply.to_string());
    }
}

#[test]
fn metrics_phases_are_accounted() {
    let weights = tiny_weights();
    let engine = InferenceEngine::start(
        weights,
        EngineConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    engine.submit(Request::new(1, vec![10, 20, 30, 40], 3)).unwrap();
    let resp = engine.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(resp.error.is_none());
    assert!(resp.timing.prefill > Duration::ZERO);
    assert!(resp.timing.decode > Duration::ZERO);
    let snap = engine.metrics().snapshot();
    assert_eq!(snap.get("completed").unwrap().as_f64(), Some(1.0));
    assert!(
        snap.get("prefill").unwrap().get("mean_us").unwrap().as_f64().unwrap() > 0.0
    );
    engine.shutdown();
}
