//! The canonical cross-backend equivalence harness.
//!
//! One table-driven property suite executes **every** [`TunedBackend`]
//! — RSR, RSR++ (dispatched and scalar-pinned), parallel, batched, and
//! the TL lookup backends — over a shared grid of blocking parameters
//! `k ∈ {1..8}`, ragged shapes (rows and cols indivisible by the block
//! width, the group size, and the SIMD lane counts), and batch sizes
//! `{1, 3, 8}`, asserting **bit-exact** outputs against the scalar
//! dense reference on integer-valued activations (every intermediate
//! f32 sum exactly representable, so any divergence is an indexing bug,
//! never rounding).
//!
//! This file replaces the per-PR copy-pasted pin patterns as the one
//! place a future backend gets added: putting a variant in
//! [`TunedBackend::ALL`] automatically enrolls it in the full grid
//! here. Keep the grid cheap enough to run under `cargo test -q`.

use std::sync::Arc;

use rsr::kernels::standard::standard_mul_ternary;
use rsr::kernels::{TernaryFlatPlan, TernaryMatrix, TernaryRsrIndex, TlPlan, TL_GROUP};
use rsr::runtime::{ExecutablePlan, SharedTernaryPlan};
use rsr::tune::TunedBackend;
use rsr::util::rng::Rng;

/// Shapes chosen for their tails: every dimension is odd or otherwise
/// indivisible by the k-window (1..8), the TL group size (4), the AVX2
/// column width (8) and the NEON column width (4).
const SHAPES: [(usize, usize); 3] = [(37, 23), (64, 48), (81, 50)];

const KS: std::ops::RangeInclusive<usize> = 1..=8;

const BATCHES: [usize; 3] = [1, 3, 8];

fn backends() -> impl Iterator<Item = TunedBackend> {
    TunedBackend::ALL.into_iter().filter(|b| b.available())
}

#[test]
fn every_backend_is_bit_exact_across_the_full_grid() {
    let mut rng = Rng::new(0xE0_01);
    for (n, m) in SHAPES {
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let v = rng.int_f32_vec(n, 3);
        let expect = standard_mul_ternary(&v, &a);
        for k in KS {
            let plan = Arc::new(
                SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, k)).unwrap(),
            );
            for backend in backends() {
                let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
                let mut out = vec![0.0f32; m];
                // Twice: scratch reuse must not change a bit.
                for round in 0..2 {
                    exec.execute(&v, &mut out).unwrap();
                    assert_eq!(
                        out,
                        expect,
                        "{n}x{m} k={k} {} round {round}",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn every_backend_batches_bit_exactly_at_every_batch_size() {
    let mut rng = Rng::new(0xE0_02);
    for (n, m) in SHAPES {
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        // One k per shape here: the k-grid is covered above, and batch
        // routing is k-independent.
        let plan = Arc::new(
            SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, 4)).unwrap(),
        );
        for backend in backends() {
            let mut exec = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap();
            for batch in BATCHES {
                let vs = rng.int_f32_vec(batch * n, 3);
                let mut out = vec![0.0f32; batch * m];
                exec.execute_batch(&vs, batch, &mut out).unwrap();
                for b in 0..batch {
                    let row = &vs[b * n..(b + 1) * n];
                    // Batched row == the same row alone through the
                    // single-vector path == the dense reference.
                    let mut solo = vec![0.0f32; m];
                    exec.execute(row, &mut solo).unwrap();
                    let got = &out[b * m..(b + 1) * m];
                    assert_eq!(
                        got,
                        &solo[..],
                        "{n}x{m} {} batch {batch} row {b} vs solo",
                        backend.name()
                    );
                    assert_eq!(
                        got,
                        &standard_mul_ternary(row, &a)[..],
                        "{n}x{m} {} batch {batch} row {b} vs reference",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn unavailable_backends_are_excluded_and_fail_cleanly() {
    // The complement of the grid: anything `available()` excludes must
    // refuse to materialize with a clean error naming the backend —
    // never a panic, never a silent wrong-ISA dispatch.
    let mut rng = Rng::new(0xE0_03);
    let a = TernaryMatrix::random(32, 16, 1.0 / 3.0, &mut rng);
    let plan =
        Arc::new(SharedTernaryPlan::new(TernaryRsrIndex::preprocess(&a, 3)).unwrap());
    for backend in TunedBackend::ALL.into_iter().filter(|b| !b.available()) {
        let err = ExecutablePlan::new(Arc::clone(&plan), backend).unwrap_err();
        assert!(err.to_string().contains(backend.name()), "{err}");
    }
}

#[test]
fn tl_plans_from_arenas_stay_exact_across_the_k_grid() {
    // TL reconstructs dense weights from the k-blocked arenas, so its
    // codes must be identical whatever k produced the plan — the
    // property that lets the tuner time TL once per layer.
    let mut rng = Rng::new(0xE0_04);
    let a = TernaryMatrix::random(53, 29, 1.0 / 3.0, &mut rng);
    let direct = TlPlan::from_weights(53, 29, TL_GROUP, a.data()).unwrap();
    for k in KS {
        let flat =
            TernaryFlatPlan::from_index(&TernaryRsrIndex::preprocess(&a, k)).unwrap();
        let via_arena = TlPlan::from_flat(&flat, TL_GROUP).unwrap();
        assert_eq!(via_arena, direct, "k={k}");
    }
}

#[test]
fn corrupt_tl_payloads_error_instead_of_panicking() {
    // Integration-level mirror of the tl.rs unit corruption tests: a
    // payload mangled the way a torn file or flipped bit would mangle
    // it must surface as Err from validation — execution never sees it.
    let mut rng = Rng::new(0xE0_05);
    let a = TernaryMatrix::random(37, 23, 1.0 / 3.0, &mut rng);
    let good = TlPlan::from_weights(37, 23, TL_GROUP, a.data()).unwrap();
    let codes = good.codes().to_vec();

    assert!(TlPlan::from_parts(37, 23, TL_GROUP, codes[..codes.len() - 1].to_vec()).is_err());
    let mut flipped = codes.clone();
    flipped[codes.len() / 2] |= 0b11;
    assert!(TlPlan::from_parts(37, 23, TL_GROUP, flipped).is_err());
    let mut grown = codes.clone();
    grown.extend_from_slice(&[0, 0]);
    assert!(TlPlan::from_parts(37, 23, TL_GROUP, grown).is_err());

    // The pristine payload round-trips and still executes exactly.
    let rebuilt = TlPlan::from_parts(37, 23, TL_GROUP, codes).unwrap();
    let v = rng.int_f32_vec(37, 3);
    let mut lut = rebuilt.scratch();
    let mut out = vec![0.0f32; 23];
    rebuilt.execute(&v, &mut out, &mut lut).unwrap();
    assert_eq!(out, standard_mul_ternary(&v, &a));
}
