//! Model-level integration: every backend produces identical greedy
//! decodes; KV-cache/decode behaviours; weight format edge cases.

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::sampler::Sampler;
use rsr::model::tokenizer::{Tokenizer, BOS};
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::util::rng::Rng;

fn tiny() -> ModelWeights {
    ModelWeights::generate(ModelConfig::tiny(), 0x301).unwrap()
}

#[test]
fn all_backends_generate_identical_tokens() {
    // The paper's §5.3 equality property, across the full backend set.
    let weights = tiny();
    let tokenizer = Tokenizer::new();
    let prompt = tokenizer.encode_with_bos("What is the capital of France?");
    let mut reference: Option<Vec<u32>> = None;
    for backend in Backend::ALL {
        let mut model = Transformer::from_weights(&weights, backend, 0).unwrap();
        let mut rng = Rng::new(0);
        let tokens = model.generate(&prompt, 10, Sampler::Greedy, &mut rng).unwrap();
        match &reference {
            None => reference = Some(tokens),
            Some(r) => {
                assert_eq!(&tokens, r, "backend {} diverged", backend.name())
            }
        }
    }
}

#[test]
fn generation_depends_on_prompt_and_weights() {
    let weights = tiny();
    let mut model = Transformer::from_weights(&weights, Backend::Standard, 0).unwrap();
    let mut rng = Rng::new(0);
    let a = model.generate(&[BOS, 65, 66], 6, Sampler::Greedy, &mut rng).unwrap();
    let b = model.generate(&[BOS, 97, 98], 6, Sampler::Greedy, &mut rng).unwrap();
    assert_ne!(a, b, "different prompts should (generically) diverge");

    let other = ModelWeights::generate(ModelConfig::tiny(), 0x999).unwrap();
    let mut model2 = Transformer::from_weights(&other, Backend::Standard, 0).unwrap();
    let c = model2.generate(&[BOS, 65, 66], 6, Sampler::Greedy, &mut rng).unwrap();
    assert_ne!(a, c, "different weights should (generically) diverge");
}

#[test]
fn kv_cache_equivalence_incremental_vs_fresh() {
    // Decoding [t0 t1 t2] incrementally must equal prefilling the whole
    // prefix at once (same cache semantics).
    let weights = tiny();
    let mut m1 = Transformer::from_weights(&weights, Backend::RsrPlusPlus, 0).unwrap();
    let mut m2 = Transformer::from_weights(&weights, Backend::RsrPlusPlus, 0).unwrap();

    m1.reset();
    let tokens = [BOS, 70, 80, 90];
    let mut last1 = Vec::new();
    for &t in &tokens {
        last1 = m1.forward_token(t).unwrap().to_vec();
    }

    m2.reset();
    for &t in &tokens {
        m2.forward_token(t).unwrap();
    }
    let last2 = m2.last_logits().to_vec();
    assert_eq!(last1, last2);
}

#[test]
fn topk_sampling_is_seed_deterministic() {
    let weights = tiny();
    let mut model = Transformer::from_weights(&weights, Backend::Standard, 0).unwrap();
    let sampler = Sampler::TopK { k: 5, temperature: 0.8 };
    let mut rng1 = Rng::new(42);
    let mut rng2 = Rng::new(42);
    let a = model.generate(&[BOS, 50], 8, sampler, &mut rng1).unwrap();
    let b = model.generate(&[BOS, 50], 8, sampler, &mut rng2).unwrap();
    assert_eq!(a, b);
}

#[test]
fn weight_file_rejects_truncation_at_every_section() {
    let weights = tiny();
    let mut buf = Vec::new();
    weights.write_to(&mut buf).unwrap();
    // Cut at a few strategic points: header, embedding, mid-layer, end.
    for cut in [2usize, 30, buf.len() / 3, buf.len() - 1] {
        let truncated = &buf[..cut];
        assert!(
            ModelWeights::read_from(&mut &truncated[..]).is_err(),
            "cut at {cut} must fail"
        );
    }
}

#[test]
fn preset_models_have_paper_band_dimensions() {
    // Paper §5.3: Llama3 matrices 2^12..2^13, Falcon3 2^11..2^12.
    let llama = ModelConfig::llama3_8b_proxy();
    assert!(llama.d_model >= 1 << 12 && llama.d_ff <= 1 << 13);
    let f3 = ModelConfig::falcon3_3b_proxy();
    assert!(f3.d_model >= 1 << 11 && f3.d_model <= 1 << 12);
    let f10 = ModelConfig::falcon3_10b_proxy();
    assert!(f10.d_model >= 1 << 11);
}

#[test]
fn weight_bytes_shrink_with_index_backends_at_scale() {
    // At Falcon-band dims the RSR index is smaller than dense i8 — the
    // model-level Fig 5 claim. (Quick mode: one layer only.)
    let mut cfg = ModelConfig::tiny();
    cfg.d_model = 1024;
    cfg.d_ff = 2048;
    cfg.n_heads = 8;
    cfg.n_kv_heads = 4;
    cfg.n_layers = 1;
    let weights = ModelWeights::generate(cfg, 0x5).unwrap();
    let std_model = Transformer::from_weights(&weights, Backend::Standard, 0).unwrap();
    let rsr_model =
        Transformer::from_weights(&weights, Backend::RsrPlusPlus, 0).unwrap();
    assert!(
        rsr_model.weight_bytes() < 2 * std_model.weight_bytes(),
        "rsr {} vs std {}",
        rsr_model.weight_bytes(),
        std_model.weight_bytes()
    );
}
