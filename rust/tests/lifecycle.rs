//! Request-lifecycle integration: deadlines, cancellation, worker
//! supervision and overload shedding, driven end-to-end through the
//! TCP server where possible.
//!
//! The invariant every scenario checks is **exactly one terminal
//! outcome per request**: whatever faults fire, a submitted request is
//! either rejected at admission or produces exactly one response
//! (ok / deadline / cancelled / failed / poisoned), `inflight` drains
//! to zero, and the response hub holds no stale waiter.
//!
//! Fault-dependent scenarios (worker panics, stalled replicas) are
//! gated on the `fault-inject` feature — the `lifecycle-chaos` CI job
//! runs `cargo test --features fault-inject --test lifecycle`; a plain
//! `cargo test` still runs the deadline/cancel/overload scenarios.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::batcher::BatchPolicy;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::request::Request;
use rsr::serving::router::Router;
use rsr::serving::client::Client;
use rsr::serving::server::{ResponseHub, Server};

fn tiny_weights() -> Arc<ModelWeights> {
    Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x5E21).unwrap())
}

/// A running server plus handles on its internals (engines for metric
/// assertions, hub for waiter-leak assertions).
struct Harness {
    addr: std::net::SocketAddr,
    engines: Vec<Arc<InferenceEngine>>,
    hub: Arc<ResponseHub>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Harness {
    fn start(
        cfgs: Vec<EngineConfig>,
        replica_stall: Option<Duration>,
        default_deadline: Option<Duration>,
    ) -> Self {
        let weights = tiny_weights();
        let engines: Vec<Arc<InferenceEngine>> = cfgs
            .into_iter()
            .map(|cfg| {
                Arc::new(InferenceEngine::start(Arc::clone(&weights), cfg).unwrap())
            })
            .collect();
        let mut router = Router::new(engines.clone()).unwrap();
        if let Some(t) = replica_stall {
            router = router.with_replica_stall(t);
        }
        let mut server = Server::new(Arc::new(router));
        if let Some(d) = default_deadline {
            server = server.with_default_deadline(d);
        }
        let hub = Arc::clone(server.hub());
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
        let bound2 = Arc::clone(&bound);
        let thread = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", stop2, move |a| {
                    *bound2.lock().unwrap() = Some(a);
                })
                .unwrap();
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        Self { addr, engines, hub, stop, thread: Some(thread) }
    }

    fn default_cfg() -> EngineConfig {
        EngineConfig { workers: 1, backend: Backend::RsrPlusPlus, ..Default::default() }
    }

    /// Block until no engine holds inflight work (panics after 30 s —
    /// a hung request is exactly the bug this file exists to catch).
    fn wait_drained(&self) {
        let t0 = Instant::now();
        while self.engines.iter().any(|e| e.inflight() > 0) {
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "request(s) hung: inflight never drained to zero"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for Harness {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Sum one counter across all replicas.
fn summed(engines: &[Arc<InferenceEngine>], f: impl Fn(&InferenceEngine) -> u64) -> u64 {
    engines.iter().map(|e| f(e)).sum()
}

// ---------------------------------------------------------------- //
// Deadlines and cancellation (no fault injection required)          //
// ---------------------------------------------------------------- //

#[test]
fn client_disconnect_frees_the_slot_and_leaves_no_waiter() {
    let h = Harness::start(vec![Harness::default_cfg()], None, None);
    // Raw connection: send one request, then vanish without reading
    // the reply. The connection thread must observe the EOF, cancel
    // the request, consume its terminal response, and exit.
    {
        let mut s = TcpStream::connect(h.addr).unwrap();
        writeln!(s, r#"{{"id": 1, "prompt": "a long question that takes a while to answer properly", "max_new": 64}}"#)
            .unwrap();
        s.flush().unwrap();
        // Dropping the stream closes the socket — the disconnect.
    }
    // Exactly one terminal outcome: the request either completed
    // before the disconnect was observed (~50 ms poll) or was
    // cancelled. Nothing may hang and no waiter may leak.
    h.wait_drained();
    let t0 = Instant::now();
    loop {
        let done = summed(&h.engines, |e| {
            e.metrics().completed.load(Ordering::Relaxed)
                + e.metrics().cancelled.load(Ordering::Relaxed)
        });
        if done == 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "expected exactly one terminal outcome, got {done}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // The connection thread consumed the response before exiting, so
    // the hub holds no stale waiter (poll: the thread needs a moment
    // between receiving the response and returning).
    let t0 = Instant::now();
    while h.hub.waiter_count() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "stale waiter left behind after disconnect"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn server_default_deadline_applies_to_requests_without_deadline_ms() {
    // A 1 ms default deadline with a long generation: the engine must
    // retire the request with the distinct deadline error (it cannot
    // finish 64 tokens before the first between-step check) — unless
    // the model EOSes immediately, in which case the reply is clean.
    // Either way: exactly one reply, nothing hangs.
    let h = Harness::start(
        vec![Harness::default_cfg()],
        None,
        Some(Duration::from_millis(1)),
    );
    let mut client = Client::connect(h.addr).unwrap();
    let reply = client
        .prompt(1, "please think very carefully about this long question")
        .max_new(64)
        .send_json()
        .unwrap();
    h.wait_drained();
    if reply.get("error").is_some() {
        let code = reply.get("code").and_then(|c| c.as_str());
        assert_eq!(code, Some("deadline_exceeded"), "unexpected error: {reply:?}");
        assert_eq!(
            summed(&h.engines, |e| {
                e.metrics().deadline_exceeded.load(Ordering::Relaxed)
            }),
            1
        );
    }
}

#[test]
fn explicit_deadline_ms_out_of_range_is_rejected() {
    let h = Harness::start(vec![Harness::default_cfg()], None, None);
    let mut client = Client::connect(h.addr).unwrap();
    let reply = client
        .send_raw(r#"{"id": 1, "prompt": "hi", "max_new": 2, "deadline_ms": 0}"#)
        .unwrap();
    assert!(reply.get("error").is_some(), "expected range error, got: {reply:?}");
    assert_eq!(
        reply.get("code").and_then(|c| c.as_str()),
        Some("bad_request"),
        "expected range error, got: {reply:?}"
    );
    // The connection still serves good requests (with a generous
    // explicit deadline this time).
    let reply =
        client.prompt(2, "still alive?").max_new(2).deadline_ms(30_000).send_json().unwrap();
    assert!(reply.get("error").is_none(), "{reply:?}");
}

// ---------------------------------------------------------------- //
// Overload (bounded queue, no fault injection required)             //
// ---------------------------------------------------------------- //

#[test]
fn overload_sheds_with_queue_full_and_every_admission_terminates() {
    let engine = InferenceEngine::start(
        tiny_weights(),
        EngineConfig {
            workers: 1,
            queue_capacity: 1,
            batch: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                max_slots: 1,
                prefill_chunk: 1,
            },
            ..Default::default()
        },
    )
    .unwrap();
    let (mut admitted, mut rejected) = (0u64, 0u64);
    for i in 0..30 {
        match engine.submit(Request::new(i, vec![3; 32], 8)) {
            Ok(()) => admitted += 1,
            Err(e) => {
                assert_eq!(
                    e.code(),
                    "queue_full",
                    "overload rejection must carry the stable code: {e}"
                );
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "a 1-deep queue must shed under a 30-request blast");
    // Every admitted request reaches exactly one terminal outcome.
    let mut responses = 0u64;
    while responses < admitted {
        assert!(
            engine.recv_timeout(Duration::from_secs(30)).is_some(),
            "admitted request never produced a response ({responses}/{admitted})"
        );
        responses += 1;
    }
    assert_eq!(engine.inflight(), 0, "inflight must drain to zero");
    let snap = engine.metrics().snapshot();
    let shed = snap.get("rejected_total").unwrap().as_f64().unwrap() as u64;
    assert_eq!(shed, rejected, "rejected_total must count every shed");
    engine.shutdown();
}

#[test]
fn saturated_router_names_the_condition_and_unregister_leaves_no_waiter() {
    // Two saturated replicas: tiny queues wedged by long sequential
    // requests. Router::submit must fail naming the backpressure, and
    // a register/unregister round trip on the hub must leave no state.
    let weights = tiny_weights();
    let cfg = || EngineConfig {
        workers: 1,
        queue_capacity: 1,
        batch: BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_slots: 1,
            prefill_chunk: 1,
        },
        ..Default::default()
    };
    let engines: Vec<Arc<InferenceEngine>> = (0..2)
        .map(|_| Arc::new(InferenceEngine::start(Arc::clone(&weights), cfg()).unwrap()))
        .collect();
    let router = Arc::new(Router::new(engines.clone()).unwrap());
    // Wedge both replicas: one request in the slot, one in the queue.
    for (i, e) in engines.iter().enumerate() {
        for j in 0..2 {
            e.submit(Request::new((i * 2 + j) as u64, vec![3; 32], 8)).unwrap();
        }
    }
    let mut saw_rejection = false;
    for i in 0..20 {
        if let Err(e) = router.submit(Request::new(100 + i, vec![3; 8], 2)) {
            assert_eq!(
                e.code(),
                "queue_full",
                "saturation error must carry the stable code: {e}"
            );
            saw_rejection = true;
            break;
        }
    }
    assert!(saw_rejection, "20 submits against two wedged 1-deep replicas must shed");
    // Hub bookkeeping: unregister removes exactly the registered entry.
    let hub = ResponseHub::start(&router);
    let _rx = hub.register(42);
    let _rx2 = hub.register(43);
    assert_eq!(hub.waiter_count(), 2);
    hub.unregister(42);
    assert_eq!(hub.waiter_count(), 1, "unregister must remove the stale waiter");
    hub.unregister(43);
    assert_eq!(hub.waiter_count(), 0);
    // Stop the dispatchers FIRST — they consume (and drop) responses
    // with no registered waiter, and would race the drain below.
    hub.shutdown();
    // Drain everything that was admitted (inflight is decremented by
    // the worker at send time, so it converges even for responses the
    // dispatchers already consumed).
    let t0 = Instant::now();
    for e in &engines {
        while e.inflight() > 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(60),
                "admitted request never reached a terminal outcome"
            );
            e.recv_timeout(Duration::from_millis(100));
        }
    }
}

// ---------------------------------------------------------------- //
// Fault injection: panics and stalls (feature-gated — the            //
// lifecycle-chaos CI job compiles these in)                          //
// ---------------------------------------------------------------- //

#[cfg(feature = "fault-inject")]
mod chaos {
    use super::*;
    use rsr::serving::engine::FaultPlan;

    /// 16 prompt tokens at the default prefill chunk of 8 put engine
    /// steps 1 and 2 mid-prefill — a panic there is deterministically
    /// a quarantine (retry) case, independent of where greedy decode
    /// happens to emit EOS.
    const LONG_PROMPT: &str = "abcdefghijklmno";

    #[test]
    fn worker_panic_mid_prefill_retries_and_answers_over_tcp() {
        let h = Harness::start(
            vec![EngineConfig {
                workers: 1,
                fault: FaultPlan { panic_at_steps: vec![2], ..Default::default() },
                ..Harness::default_cfg()
            }],
            None,
            None,
        );
        let mut client = Client::connect(h.addr).unwrap();
        let reply = client.prompt(1, LONG_PROMPT).max_new(4).send_json().unwrap();
        assert!(
            reply.get("error").is_none(),
            "mid-prefill panic must quarantine and retry, got {reply:?}"
        );
        h.wait_drained();
        assert_eq!(h.engines[0].panics_total(), 1, "exactly one supervised panic");
        // The worker respawned: a second request is served cleanly.
        let reply = client.prompt(2, "still serving?").max_new(2).send_json().unwrap();
        assert!(reply.get("error").is_none(), "{reply:?}");
    }

    #[test]
    fn second_panic_poisons_the_request_over_tcp() {
        let h = Harness::start(
            vec![EngineConfig {
                workers: 1,
                fault: FaultPlan { panic_at_steps: vec![2, 3], ..Default::default() },
                ..Harness::default_cfg()
            }],
            None,
            None,
        );
        let mut client = Client::connect(h.addr).unwrap();
        // Step 2 panics mid-prefill (quarantine), the retry's first
        // step is 3 (panics again) — the request must be poisoned, not
        // retried forever.
        let reply = client.prompt(1, LONG_PROMPT).max_new(4).send_json().unwrap();
        // Poisoning has no dedicated wire code (it maps to the
        // `internal` catch-all), so the prose is the discriminator.
        let err = reply.get("error").and_then(|e| e.as_str()).unwrap_or("");
        assert!(err.contains("poisoned"), "expected poisoned, got {reply:?}");
        h.wait_drained();
        assert_eq!(h.engines[0].panics_total(), 2);
        // Poisoning one request must not poison the worker.
        let reply = client.prompt(2, "next customer").max_new(2).send_json().unwrap();
        assert!(reply.get("error").is_none(), "{reply:?}");
    }

    #[test]
    fn deadline_expiring_mid_stall_returns_the_distinct_error() {
        // The worker stalls 400 ms inside its first step; a 100 ms
        // deadline expires during the stall and the between-step sweep
        // must retire the request with the deadline error — well inside
        // the server's grace window, so the client sees the reply.
        let h = Harness::start(
            vec![EngineConfig {
                workers: 1,
                fault: FaultPlan { stall_at_step: Some((1, 400)), ..Default::default() },
                ..Harness::default_cfg()
            }],
            None,
            None,
        );
        let mut client = Client::connect(h.addr).unwrap();
        let reply =
            client.prompt(1, LONG_PROMPT).max_new(8).deadline_ms(100).send_json().unwrap();
        assert_eq!(
            reply.get("code").and_then(|c| c.as_str()),
            Some("deadline_exceeded"),
            "got {reply:?}"
        );
        h.wait_drained();
        assert_eq!(
            h.engines[0].metrics().deadline_exceeded.load(Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn stalled_replica_is_routed_around_over_tcp() {
        // Replica 0 wedges 600 ms inside its first step; with a 100 ms
        // stall threshold the router must serve new traffic from
        // replica 1 while 0 is dark.
        let h = Harness::start(
            vec![
                EngineConfig {
                    workers: 1,
                    fault: FaultPlan {
                        stall_at_step: Some((1, 600)),
                        ..Default::default()
                    },
                    ..Harness::default_cfg()
                },
                Harness::default_cfg(),
            ],
            Some(Duration::from_millis(100)),
            None,
        );
        // Wedge replica 0 directly (bypassing the router).
        h.engines[0].submit(Request::new(900, vec![10, 20, 30], 2)).unwrap();
        std::thread::sleep(Duration::from_millis(250));
        assert!(
            h.engines[0].heartbeat_age() > Duration::from_millis(100),
            "replica 0 must look stalled (age {:?})",
            h.engines[0].heartbeat_age()
        );
        // A TCP request during the stall must be answered promptly by
        // the healthy replica — not queued behind the wedged one.
        let t0 = Instant::now();
        let mut client = Client::connect(h.addr).unwrap();
        let reply = client.prompt(1, "who serves me?").max_new(2).send_json().unwrap();
        assert!(reply.get("error").is_none(), "{reply:?}");
        // Discriminating bound: the wedge clears 600 ms after the
        // direct submit (~350 ms from here), so a reply queued behind
        // replica 0 cannot arrive before this deadline.
        assert!(
            t0.elapsed() < Duration::from_millis(340),
            "reply took {:?} — it queued behind the stalled replica",
            t0.elapsed()
        );
        assert_eq!(
            h.engines[1].metrics().completed.load(Ordering::Relaxed),
            1,
            "the healthy replica must have served the request"
        );
        h.wait_drained();
    }
}
