//! Memory-governance integration: budget-capped KV serving over real
//! TCP.
//!
//! The invariants every scenario checks, with a deliberately tiny
//! `--kv-budget`:
//!
//! * **exactly one terminal outcome per request** — completed, or the
//!   named `kv budget exceeded` error (admission shed, seating
//!   refusal, or youngest-first eviction); never a hang, never a
//!   silent drop,
//! * **completed outputs are token-identical** to the same prompts on
//!   an unbudgeted server — the budget degrades capacity, never math,
//! * the response hub holds no stale waiter, lifecycle conservation
//!   (`admitted == terminals + inflight`) holds with the new
//!   `kv_budget_exceeded` terminal class, and the page pool drains to
//!   zero once the engine idles.
//!
//! The deterministic forced-eviction scenario is gated on the
//! `fault-inject` feature (the lifecycle-chaos CI job compiles it in);
//! the budget-pressure scenarios run under a plain `cargo test`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::router::Router;
use rsr::serving::client::Client;
use rsr::serving::server::{ResponseHub, Server};
use rsr::util::json::Json;

fn tiny_weights() -> Arc<ModelWeights> {
    Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x5E21).unwrap())
}

/// A running server plus handles on its internals (same shape as the
/// lifecycle harness: engines for counter assertions, hub for
/// waiter-leak assertions).
struct Harness {
    addr: std::net::SocketAddr,
    engines: Vec<Arc<InferenceEngine>>,
    hub: Arc<ResponseHub>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Harness {
    fn start(cfg: EngineConfig) -> Self {
        let weights = tiny_weights();
        let engines =
            vec![Arc::new(InferenceEngine::start(weights, cfg).unwrap())];
        let router = Arc::new(Router::new(engines.clone()).unwrap());
        let server = Server::new(router);
        let hub = Arc::clone(server.hub());
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
        let bound2 = Arc::clone(&bound);
        let thread = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", stop2, move |a| {
                    *bound2.lock().unwrap() = Some(a);
                })
                .unwrap();
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        Self { addr, engines, hub, stop, thread: Some(thread) }
    }

    fn engine(&self) -> &InferenceEngine {
        &self.engines[0]
    }

    /// Block until inflight drains, the hub holds no waiter, and the
    /// KV pool reads zero pages in use (panics after 30 s — a hung
    /// request or a leaked page is exactly what this file catches).
    fn wait_quiescent(&self) {
        let t0 = Instant::now();
        loop {
            let e = self.engine();
            if e.inflight() == 0
                && self.hub.waiter_count() == 0
                && e.kv_pool().pages_in_use() == 0
            {
                return;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "engine never quiesced: inflight={} waiters={} pages_in_use={}",
                e.inflight(),
                self.hub.waiter_count(),
                e.kv_pool().pages_in_use()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for Harness {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// tiny: kv_dim = 2 kv-heads × 16 head-dim = 32 floats → a 4-token
/// page is 2·4·32·4 = 1024 bytes, so this budget holds exactly
/// `pages` pages across the model's 2 layers.
fn budgeted_cfg(pages: u64) -> EngineConfig {
    EngineConfig {
        workers: 1,
        backend: Backend::RsrPlusPlus,
        kv_budget: Some(pages * 1024),
        kv_page_tokens: 4,
        ..Default::default()
    }
}

fn tokens_of(reply: &Json) -> Vec<u64> {
    reply
        .get("tokens")
        .expect("ok replies carry tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u64)
        .collect()
}

fn snapshot_num(engine: &InferenceEngine, key: &str) -> f64 {
    engine.snapshot().get(key).unwrap().as_f64().unwrap()
}

#[test]
fn budget_pressure_yields_exactly_one_terminal_outcome_per_request() {
    // Reference pass first: the same prompt mix on an UNBUDGETED
    // server pins the expected tokens per prompt.
    let prompts: Vec<String> =
        (0..14).map(|i| format!("client {i:02} asks a question")).collect();
    let reference: HashMap<usize, Vec<u64>> = {
        let h = Harness::start(EngineConfig {
            workers: 1,
            backend: Backend::RsrPlusPlus,
            ..Default::default()
        });
        let mut client = Client::connect(h.addr).unwrap();
        let map = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let reply =
                    client.prompt(i as u64, p).max_new(24).send_json().unwrap();
                assert!(reply.get("error").is_none(), "{reply:?}");
                (i, tokens_of(&reply))
            })
            .collect();
        h.wait_quiescent();
        map
    };

    // 26 pages: one 25-token prompt needs 7 pages × 2 layers = 14 at
    // admission and grows to exactly 2·pages_for(25+24) = 26 at full
    // decode length — a lone sequence fits, two concurrent ones
    // cannot, so the blast must shed or evict while the oldest always
    // finishes. An 80-token prompt needs 2·20 = 40 pages — impossible
    // even on an empty pool, so one admission shed is deterministic.
    let h = Harness::start(budgeted_cfg(26));
    {
        let mut c = Client::connect(h.addr).unwrap();
        let reply = c.prompt(900, &"x".repeat(80)).max_new(4).send_json().unwrap();
        assert_eq!(
            reply.get("code").and_then(|c| c.as_str()),
            Some("kv_budget_exceeded"),
            "oversized prompt must be shed with the stable code, got {reply:?}"
        );
    }
    // 7 concurrent clients, two requests each: every reply must be a
    // completion (token-identical to the reference) or the named
    // budget error — nothing else, and nothing may hang.
    let addr = h.addr;
    let results: Vec<(usize, Json)> = {
        let handles: Vec<_> = (0..7)
            .map(|c| {
                let prompts = prompts.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut out = Vec::new();
                    for j in [c, c + 7] {
                        let reply = client
                            .prompt(j as u64, &prompts[j])
                            .max_new(24)
                            .send_json()
                            .unwrap();
                        out.push((j, reply));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|t| t.join().unwrap()).collect()
    };
    assert_eq!(results.len(), prompts.len(), "every request got exactly one reply");
    let mut completed = 0usize;
    let mut shed = 0usize;
    for (i, reply) in &results {
        if reply.get("error").is_none() {
            assert_eq!(
                &tokens_of(reply),
                reference.get(i).unwrap(),
                "prompt {i}: budgeted completion diverged from the \
                 unbudgeted reference"
            );
            completed += 1;
        } else {
            assert_eq!(
                reply.get("code").and_then(|c| c.as_str()),
                Some("kv_budget_exceeded"),
                "prompt {i}: only the budget code may appear under pure \
                 KV pressure, got: {reply:?}"
            );
            shed += 1;
        }
    }
    assert_eq!(completed + shed, prompts.len());
    assert!(completed > 0, "the oldest sequence always has headroom to finish");

    h.wait_quiescent();
    let e = h.engine();
    // Conservation holds with the kv_budget_exceeded terminal class
    // carrying every shed and eviction (+1 for the oversized prompt).
    assert_eq!(snapshot_num(e, "kv_budget_exceeded_total"), (shed + 1) as f64);
    assert_eq!(
        snapshot_num(e, "admitted"),
        snapshot_num(e, "completed")
            + snapshot_num(e, "failed")
            + snapshot_num(e, "deadline_exceeded_total")
            + snapshot_num(e, "cancelled_total")
            + snapshot_num(e, "kv_budget_exceeded_total")
    );
    assert!(matches!(e.snapshot().get("conserved"), Some(Json::Bool(true))));
    // The pool saw real traffic and accounted it.
    assert!(e.kv_pool().peak_pages_in_use() > 0);
    assert!(e.kv_pool().peak_pages_in_use() <= e.kv_pool().total_pages());
    assert!(
        e.kv_pool().reservations_failed() + e.kv_pool().evictions() >= 1,
        "the oversized prompt alone guarantees one reservation failure"
    );
}

#[test]
fn generous_budget_serves_token_identically_to_no_budget() {
    // `--kv-budget` large enough to never bind must be invisible:
    // same prompts, same tokens, zero sheds, zero evictions.
    let prompts: Vec<String> =
        (0..4).map(|i| format!("steady request number {i}")).collect();
    let run = |cfg: EngineConfig| -> Vec<Vec<u64>> {
        let h = Harness::start(cfg);
        let mut client = Client::connect(h.addr).unwrap();
        let out = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let reply = client.prompt(i as u64, p).max_new(12).send_json().unwrap();
                assert!(reply.get("error").is_none(), "{reply:?}");
                tokens_of(&reply)
            })
            .collect();
        h.wait_quiescent();
        assert_eq!(h.engine().kv_pool().reservations_failed(), 0);
        assert_eq!(h.engine().kv_pool().evictions(), 0);
        out
    };
    let unbudgeted = run(EngineConfig {
        workers: 1,
        backend: Backend::RsrPlusPlus,
        ..Default::default()
    });
    assert_eq!(run(budgeted_cfg(4096)), unbudgeted);
}

// ---------------------------------------------------------------- //
// Fault injection: deterministic forced eviction (feature-gated —   //
// the lifecycle-chaos CI job compiles these in)                     //
// ---------------------------------------------------------------- //

#[cfg(feature = "fault-inject")]
mod chaos {
    use super::*;
    use rsr::serving::engine::FaultPlan;

    #[test]
    fn forced_exhaustion_evicts_over_tcp_and_the_server_keeps_serving() {
        // `exhaust_kv_at_step: 2` fires the pressure checkpoint while
        // the first request is mid-flight (a 16-token prompt at the
        // default prefill chunk of 8 spans steps 1–2): the youngest —
        // only — slot is retired with the named budget error, the
        // client sees exactly one reply, and the next request serves
        // cleanly.
        let h = Harness::start(EngineConfig {
            workers: 1,
            backend: Backend::RsrPlusPlus,
            fault: FaultPlan { exhaust_kv_at_step: Some(2), ..Default::default() },
            ..Default::default()
        });
        let mut client = Client::connect(h.addr).unwrap();
        let reply = client.prompt(1, "abcdefghijklmnop").max_new(8).send_json().unwrap();
        assert_eq!(
            reply.get("code").and_then(|c| c.as_str()),
            Some("kv_budget_exceeded"),
            "got {reply:?}"
        );
        // Eviction vs admission-shed has no dedicated code — the prose
        // is the only discriminator for this sub-case.
        let err = reply.get("error").and_then(|e| e.as_str()).unwrap_or("");
        assert!(err.contains("evicted under page pressure"), "got {reply:?}");
        let reply = client.prompt(2, "next customer").max_new(4).send_json().unwrap();
        assert!(reply.get("error").is_none(), "{reply:?}");
        h.wait_quiescent();
        let e = h.engine();
        assert_eq!(e.kv_pool().evictions(), 1);
        assert_eq!(snapshot_num(e, "kv_budget_exceeded_total"), 1.0);
        assert!(matches!(e.snapshot().get("conserved"), Some(Json::Bool(true))));
    }
}
