//! Chunked prefill equivalence — the correctness spine of the chunked
//! prefill PR: feeding a prompt in chunks of ≥ 2 tokens through
//! [`Transformer::forward_chunk`] must produce **bit-identical** logits
//! and greedy tokens to feeding it one token per step, because per row
//! the batched flat kernels perform the identical f32 addition sequence
//! at every batch size and the attention window of a chunk row is
//! truncated to its own position.
//!
//! Covered here:
//! * logit + greedy-token bit-exactness of chunk ∈ {2, 8, prompt_len}
//!   vs chunk 1 across **every** `TunedBackend` (profile-forced stores)
//!   and the untuned shared-plan store,
//! * ragged prompts shorter than the chunk,
//! * a chunk boundary landing mid-prompt while decode slots are live in
//!   the same lockstep step (mixed counts),
//! * engine-level equality of `--prefill-chunk {1, 2, 8}` under mixed
//!   prompt lengths, and the TTFT / prefill-throughput metrics.

use std::sync::Arc;
use std::time::Duration;

use rsr::model::config::ModelConfig;
use rsr::model::tensor::argmax;
use rsr::model::tokenizer::EOS;
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::runtime::PlanStore;
use rsr::serving::batcher::BatchPolicy;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::request::Request;
use rsr::tune::{LayerChoice, LayerProfile, MachineFingerprint, TuneProfile, TunedBackend};

fn tiny_weights() -> ModelWeights {
    ModelWeights::generate(ModelConfig::tiny(), 42).unwrap()
}

/// A profile forcing one `(backend, k)` on every layer — the same
/// helper the tune tests use, so every `TunedBackend` can be pinned
/// under the chunk path.
fn forced_profile(weights: &ModelWeights, backend: TunedBackend, k: usize) -> TuneProfile {
    let layers = weights
        .named_matrices()
        .into_iter()
        .map(|(name, m, _scale)| LayerProfile {
            name,
            rows: m.rows(),
            cols: m.cols(),
            chain: vec![LayerChoice { backend, k, ns: 1.0 }],
        })
        .collect();
    TuneProfile::new(MachineFingerprint::current(), layers).unwrap()
}

/// Greedy lockstep driver mirroring the engine's continuous loop with
/// chunked prefill: slot `s` prefills its prompt `chunks[s]` tokens per
/// step (ragged tail included), then decodes greedily to `max_new[s]`.
/// Returns, per slot, the per-position prefill logits (the bit-exact
/// artifact) and the generated tokens.
fn drive(
    model: &mut Transformer,
    prompts: &[Vec<u32>],
    max_new: &[usize],
    chunks: &[usize],
) -> (Vec<Vec<Vec<f32>>>, Vec<Vec<u32>>) {
    let n = prompts.len();
    model.ensure_slots(n);
    for s in 0..n {
        model.reset_slot(s);
    }
    let vocab = model.config().vocab_size;
    let max_seq = model.config().max_seq_len;
    let mut prefill_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut outs: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut pos = vec![0usize; n];
    let mut next = vec![0u32; n];
    let mut done = vec![false; n];
    while done.iter().any(|&d| !d) {
        let mut slots = Vec::new();
        let mut counts = Vec::new();
        let mut tokens = Vec::new();
        for s in 0..n {
            if done[s] {
                continue;
            }
            if pos[s] < prompts[s].len() {
                let take = chunks[s].max(1).min(prompts[s].len() - pos[s]);
                tokens.extend_from_slice(&prompts[s][pos[s]..pos[s] + take]);
                counts.push(take);
            } else {
                tokens.push(next[s]);
                counts.push(1);
            }
            slots.push(s);
        }
        let logits = model.forward_chunk(&tokens, &slots, &counts).unwrap().to_vec();
        let mut row0 = 0usize;
        for (i, &s) in slots.iter().enumerate() {
            let c = counts[i];
            let last = row0 + c - 1;
            if pos[s] < prompts[s].len() {
                for r in row0..row0 + c {
                    prefill_logits[s].push(logits[r * vocab..(r + 1) * vocab].to_vec());
                }
                pos[s] += c;
                if pos[s] < prompts[s].len() {
                    row0 += c;
                    continue;
                }
            }
            let nt = argmax(&logits[last * vocab..(last + 1) * vocab]) as u32;
            outs[s].push(nt);
            let fed = model.seq_len_slot(s);
            if outs[s].len() >= max_new[s] || nt == EOS || fed >= max_seq {
                done[s] = true;
            } else {
                next[s] = nt;
            }
            row0 += c;
        }
    }
    (prefill_logits, outs)
}

#[test]
fn chunked_prefill_is_bit_identical_across_every_tuned_backend() {
    // The acceptance criterion: chunk ∈ {2, 8, prompt_len} vs chunk 1,
    // per-position prefill logits assert_eq-exact and greedy
    // continuation token-for-token, on the untuned shared store and on
    // a profile-forced store for EVERY TunedBackend (including the
    // batched kernel itself and the parallel pool).
    let w = tiny_weights();
    let prompt: Vec<u32> = "What is 2+2?".bytes().map(|b| b as u32).collect();
    let k = rsr::kernels::optimal_k::optimal_k_rsrpp(w.config.d_model);
    let mut stores: Vec<(String, PlanStore)> =
        vec![("untuned".into(), PlanStore::for_model(Arc::new(w.clone()), 0))];
    for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
        let store = PlanStore::for_model(Arc::new(w.clone()), 0)
            .with_profile(forced_profile(&w, backend, k))
            .unwrap();
        stores.push((format!("tuned-{}", backend.name()), store));
    }
    for (name, store) in &stores {
        let mut base_model = Transformer::from_plan_store(&w, store).unwrap();
        let (base_logits, base_tokens) =
            drive(&mut base_model, &[prompt.clone()], &[6], &[1]);
        assert_eq!(base_logits[0].len(), prompt.len(), "{name}");
        assert!(!base_tokens[0].is_empty(), "{name}");
        for chunk in [2usize, 8, prompt.len()] {
            let mut m = Transformer::from_plan_store(&w, store).unwrap();
            let (logits, tokens) = drive(&mut m, &[prompt.clone()], &[6], &[chunk]);
            assert_eq!(
                logits[0], base_logits[0],
                "{name}: chunk {chunk} prefill logits diverged from chunk 1"
            );
            assert_eq!(
                tokens[0], base_tokens[0],
                "{name}: chunk {chunk} greedy tokens diverged from chunk 1"
            );
        }
    }
}

#[test]
fn ragged_prompt_shorter_than_the_chunk_is_exact() {
    // A 2-token prompt under chunk 8: one partial chunk covers the
    // whole prompt. Must equal the chunk-1 run bit for bit.
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let prompt = vec![9u32, 201];
    let mut a = Transformer::from_plan_store(&w, &store).unwrap();
    let mut b = Transformer::from_plan_store(&w, &store).unwrap();
    let (la, ta) = drive(&mut a, &[prompt.clone()], &[5], &[1]);
    let (lb, tb) = drive(&mut b, &[prompt.clone()], &[5], &[8]);
    assert_eq!(la, lb, "ragged chunk prefill logits diverged");
    assert_eq!(ta, tb, "ragged chunk greedy tokens diverged");
}

#[test]
fn chunk_boundary_mid_prompt_with_live_decode_slots_perturbs_no_one() {
    // Slot 0 has a 1-token prompt, so it is decoding from the second
    // step on while slot 1 is still mid-prompt: the lockstep steps mix
    // a decode row with a 4-token chunk, and slot 1's chunk boundary
    // (10 tokens = 4 + 4 + 2) lands mid-prompt twice. Both slots must
    // match their solo runs bit for bit, and slot 1 must match its own
    // chunk-1 solo run.
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let p0 = vec![77u32];
    let p1: Vec<u32> = (0..10u32).map(|j| 30 + j * 3).collect();

    let mut mixed = Transformer::from_plan_store(&w, &store).unwrap();
    let (logits, tokens) =
        drive(&mut mixed, &[p0.clone(), p1.clone()], &[12, 6], &[1, 4]);

    let mut solo0 = Transformer::from_plan_store(&w, &store).unwrap();
    let (l0, t0) = drive(&mut solo0, &[p0.clone()], &[12], &[1]);
    assert_eq!(logits[0], l0[0], "decode slot perturbed by a batchmate's chunk");
    assert_eq!(tokens[0], t0[0], "decode tokens perturbed by a batchmate's chunk");

    let mut solo1 = Transformer::from_plan_store(&w, &store).unwrap();
    let (l1, t1) = drive(&mut solo1, &[p1.clone()], &[6], &[1]);
    assert_eq!(logits[1], l1[0], "chunked slot diverged from its chunk-1 solo run");
    assert_eq!(tokens[1], t1[0], "chunked tokens diverged from the chunk-1 solo run");
}

/// Run one engine at the given prefill chunk over a fixed request mix;
/// returns the responses ordered by id.
fn run_engine(
    weights: &Arc<ModelWeights>,
    prefill_chunk: usize,
    reqs: &[(u64, Vec<u32>, usize)],
) -> Vec<(u64, Vec<u32>)> {
    let engine = InferenceEngine::start(
        Arc::clone(weights),
        EngineConfig {
            workers: 1,
            batch: BatchPolicy { max_slots: 3, prefill_chunk, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    for (id, p, m) in reqs {
        engine.submit(Request::new(*id, p.clone(), *m)).unwrap();
    }
    let mut out = Vec::new();
    for _ in 0..reqs.len() {
        let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
        assert!(r.error.is_none(), "{:?}", r.error);
        out.push((r.id, r.tokens));
    }
    engine.shutdown();
    out.sort_by_key(|(id, _)| *id);
    out
}

#[test]
fn engine_prefill_chunks_agree_token_for_token() {
    // Mixed prompt lengths: shorter than the chunk, exactly the chunk,
    // spanning several chunks — plus more requests than slots, so
    // chunked prefill runs while decode slots are live and slots are
    // reused after retirement. --prefill-chunk {2, 8} must match the
    // chunk-1 engine exactly.
    let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x99).unwrap());
    let reqs: Vec<(u64, Vec<u32>, usize)> = vec![
        (1, vec![5, 6, 7], 10),
        (2, (0..17u32).map(|j| 40 + j).collect(), 6),
        (3, vec![200], 8),
        (4, (0..8u32).map(|j| 90 + j * 2).collect(), 4),
        (5, vec![10, 20, 30, 40, 50], 12),
    ];
    let base = run_engine(&weights, 1, &reqs);
    for chunk in [2usize, 8] {
        assert_eq!(
            run_engine(&weights, chunk, &reqs),
            base,
            "--prefill-chunk {chunk} must serve the chunk-1 tokens"
        );
    }
}

#[test]
fn engine_reports_ttft_and_prefill_throughput() {
    let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x99).unwrap());
    let engine = InferenceEngine::start(
        Arc::clone(&weights),
        EngineConfig { workers: 1, ..Default::default() },
    )
    .unwrap();
    let prompts = [vec![5u32; 12], vec![8u32; 20]];
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(Request::new(i as u64, p.clone(), 3)).unwrap();
    }
    for _ in 0..prompts.len() {
        let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    let snap = engine.metrics().snapshot();
    let ttft = snap.get("ttft_us").unwrap();
    assert_eq!(ttft.get("count").unwrap().as_f64(), Some(2.0));
    assert!(ttft.get("mean_us").unwrap().as_f64().unwrap() > 0.0);
    // 32 prompt tokens consumed across the two requests.
    assert_eq!(snap.get("prefill_tokens").unwrap().as_f64(), Some(32.0));
    assert!(snap.get("prefill_tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
    engine.shutdown();
}

#[test]
fn paged_kv_cache_is_bit_identical_to_the_default_layout() {
    // The memory-governance acceptance pin: a model whose KV caches
    // draw fixed-size pages from a shared pool — across page sizes
    // that force many page boundaries mid-prompt and mid-decode, and
    // under a bounded (but sufficient) budget — must produce the SAME
    // per-position prefill logits and greedy tokens as the default
    // construction, bit for bit. Attention reads one position at a
    // time, so the page table must be invisible to the math.
    use rsr::runtime::KvPool;
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let prompts = [
        (0..10u32).map(|j| 30 + j * 3).collect::<Vec<u32>>(),
        vec![77u32, 5, 201],
    ];
    let max_new = [8usize, 12];
    let chunks = [4usize, 1];

    let mut base = Transformer::from_plan_store(&w, &store).unwrap();
    let (base_logits, base_tokens) = drive(&mut base, &prompts, &max_new, &chunks);

    let kv_dim = w.config.n_kv_heads * w.config.head_dim();
    let pools: Vec<(String, Arc<KvPool>)> = vec![
        ("unbounded-pt1".into(), Arc::new(KvPool::unbounded(1))),
        ("unbounded-pt2".into(), Arc::new(KvPool::unbounded(2))),
        ("unbounded-pt64".into(), Arc::new(KvPool::unbounded(64))),
        (
            "bounded-pt4".into(),
            Arc::new(KvPool::bounded(4, kv_dim, 4 << 20).unwrap()),
        ),
    ];
    for (name, pool) in pools {
        let mut m =
            Transformer::from_plan_store_pooled(&w, &store, Arc::clone(&pool)).unwrap();
        let (logits, tokens) = drive(&mut m, &prompts, &max_new, &chunks);
        assert_eq!(logits, base_logits, "{name}: paged prefill logits diverged");
        assert_eq!(tokens, base_tokens, "{name}: paged greedy tokens diverged");
        drop(m);
        assert_eq!(pool.pages_in_use(), 0, "{name}: dropped model must return pages");
    }
}

#[test]
fn single_chunk_prefill_matches_generate() {
    // Whole-prompt chunks through the public generate()-equivalent
    // sequence: prefill in ONE chunk, then greedy forward_batch decode,
    // vs the seed's token-by-token generate() on the same shared store
    // — greedy tokens must match (same kernels per row, so bitwise).
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let prompt = vec![11u32, 45, 99, 120, 7];
    let mut seq = Transformer::from_plan_store(&w, &store).unwrap();
    let mut rng = rsr::util::rng::Rng::new(0);
    let expect = seq
        .generate(&prompt, 6, rsr::model::sampler::Sampler::Greedy, &mut rng)
        .unwrap();
    let mut m = Transformer::from_plan_store(&w, &store).unwrap();
    let (_, got) = drive(&mut m, &[prompt.clone()], &[6], &[prompt.len()]);
    assert_eq!(got[0], expect, "one-chunk prefill + decode must match generate()");
}
