//! Continuous batched decode equivalence: the lockstep pipeline must
//! serve exactly the tokens sequential decode serves — across RSR
//! backends, under ragged completion (sequences finishing at different
//! steps), under mid-flight joins, and on the `B = 1` degenerate path.
//!
//! Two kinds of guarantee are asserted:
//!
//! * **Exact invariance** — per row, the batched flat kernel performs
//!   the identical f32 addition sequence at every batch size, so a
//!   sequence's tokens are bit-independent of its batchmates. Ragged
//!   and mid-flight tests compare batched runs against solo runs
//!   through the same batched pipeline with `assert_eq!`.
//! * **Cross-kernel greedy identity** — batched vs the single-vector
//!   kernels re-associate sums differently, so those comparisons are
//!   token-level greedy identity on the tiny model, the same check the
//!   seed's cross-backend test (`Standard` vs `Rsr` vs `RsrPlusPlus`)
//!   has always made.

use std::sync::Arc;
use std::time::Duration;

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::sampler::Sampler;
use rsr::model::tensor::argmax;
use rsr::model::tokenizer::EOS;
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::runtime::PlanStore;
use rsr::serving::batcher::BatchPolicy;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::request::Request;
use rsr::util::rng::Rng;

fn tiny_weights() -> ModelWeights {
    ModelWeights::generate(ModelConfig::tiny(), 42).unwrap()
}

/// Greedy continuous decode at the model level, mirroring the engine's
/// lockstep loop: slot `s` joins at step `join_at[s]`, prefills its
/// prompt one token per step, then decodes until its own `max_new[s]`
/// budget (or EOS / context limit) — so batches are ragged and slots
/// retire mid-flight.
fn lockstep_staggered(
    model: &mut Transformer,
    prompts: &[Vec<u32>],
    max_new: &[usize],
    join_at: &[usize],
) -> Vec<Vec<u32>> {
    let n = prompts.len();
    model.ensure_slots(n);
    let vocab = model.config().vocab_size;
    let max_seq = model.config().max_seq_len;
    let mut outs: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut pos = vec![0usize; n];
    let mut fed = vec![0usize; n];
    let mut next: Vec<u32> = prompts.iter().map(|p| p[0]).collect();
    let mut joined = vec![false; n];
    let mut live: Vec<usize> = Vec::new();
    let mut step = 0usize;
    loop {
        for s in 0..n {
            if !joined[s] && join_at[s] <= step {
                joined[s] = true;
                model.reset_slot(s);
                live.push(s);
            }
        }
        live.sort_unstable();
        if live.is_empty() {
            if joined.iter().all(|&j| j) {
                break;
            }
            step += 1;
            continue;
        }
        let tokens: Vec<u32> = live.iter().map(|&s| next[s]).collect();
        let slots = live.clone();
        let logits = model.forward_batch(&tokens, &slots).unwrap().to_vec();
        let mut still = Vec::new();
        for (row, &s) in slots.iter().enumerate() {
            fed[s] += 1;
            if pos[s] + 1 < prompts[s].len() {
                pos[s] += 1;
                next[s] = prompts[s][pos[s]];
                still.push(s);
                continue;
            }
            pos[s] = prompts[s].len();
            if max_new[s] == 0 {
                continue;
            }
            let nt = argmax(&logits[row * vocab..(row + 1) * vocab]) as u32;
            outs[s].push(nt);
            let done = outs[s].len() >= max_new[s] || nt == EOS || fed[s] >= max_seq;
            if !done {
                next[s] = nt;
                still.push(s);
            }
        }
        live = still;
        step += 1;
    }
    outs
}

fn lockstep(model: &mut Transformer, prompts: &[Vec<u32>], max_new: &[usize]) -> Vec<Vec<u32>> {
    lockstep_staggered(model, prompts, max_new, &vec![0; prompts.len()])
}

#[test]
fn batched_matches_sequential_generate_across_all_backends() {
    // The seed's cross-backend prompt/length: greedy tokens are known
    // stable across accumulation orders on this model.
    let w = tiny_weights();
    let prompt: Vec<u32> = "What is 2+2?".bytes().map(|b| b as u32).collect();
    for backend in Backend::ALL {
        let mut seq = Transformer::from_weights(&w, backend, 0).unwrap();
        let mut rng = Rng::new(0);
        let expect = seq.generate(&prompt, 8, Sampler::Greedy, &mut rng).unwrap();
        let mut batched = Transformer::from_weights(&w, backend, 0).unwrap();
        let got =
            lockstep(&mut batched, &[prompt.clone(), prompt.clone(), prompt.clone()], &[8; 3]);
        for (i, g) in got.iter().enumerate() {
            assert_eq!(g, &expect, "{} slot {i}", backend.name());
        }
    }
}

#[test]
fn plan_store_batched_matches_sequential_generate() {
    // The production path: store-shared plans, batched flat kernel.
    let w = tiny_weights();
    let prompt: Vec<u32> = "What is 2+2?".bytes().map(|b| b as u32).collect();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let mut seq = Transformer::from_plan_store(&w, &store).unwrap();
    let mut rng = Rng::new(0);
    let expect = seq.generate(&prompt, 8, Sampler::Greedy, &mut rng).unwrap();
    let mut batched = Transformer::from_plan_store(&w, &store).unwrap();
    let got = lockstep(&mut batched, &[prompt.clone(), prompt.clone()], &[8; 2]);
    for (i, g) in got.iter().enumerate() {
        assert_eq!(g, &expect, "plan-store batched slot {i} vs sequential");
    }
}

#[test]
fn ragged_completion_is_bit_identical_to_solo_decode() {
    // Four sequences with different prompts and budgets: the batch
    // shrinks as each finishes. Every sequence must produce exactly
    // the tokens it produces alone through the same batched pipeline —
    // rows are independent of batchmates, so this is assert_eq-exact.
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let prompts: Vec<Vec<u32>> =
        vec![vec![5, 6, 7], vec![10, 20, 30, 40, 50], vec![9], vec![100, 101]];
    let budgets = [3usize, 10, 6, 1];
    let mut batched = Transformer::from_plan_store(&w, &store).unwrap();
    let ragged = lockstep(&mut batched, &prompts, &budgets);
    for (i, p) in prompts.iter().enumerate() {
        let mut solo = Transformer::from_plan_store(&w, &store).unwrap();
        let alone = lockstep(&mut solo, &[p.clone()], &budgets[i..=i]);
        assert_eq!(ragged[i], alone[0], "slot {i} diverged from its solo run");
        assert!(ragged[i].len() <= budgets[i]);
        assert!(!ragged[i].is_empty());
    }
}

#[test]
fn mid_flight_joins_do_not_perturb_running_sequences() {
    // Slot 1 joins four steps into slot 0's decode; slot 2 joins later
    // still. Every sequence must match its solo run bit for bit.
    let w = tiny_weights();
    let store = PlanStore::for_model(Arc::new(w.clone()), 0);
    let prompts: Vec<Vec<u32>> = vec![vec![11, 12, 13], vec![40, 41], vec![70, 71, 72]];
    let budgets = [10usize, 6, 4];
    let mut batched = Transformer::from_plan_store(&w, &store).unwrap();
    let joined = lockstep_staggered(&mut batched, &prompts, &budgets, &[0, 4, 7]);
    for (i, p) in prompts.iter().enumerate() {
        let mut solo = Transformer::from_plan_store(&w, &store).unwrap();
        let alone = lockstep(&mut solo, &[p.clone()], &budgets[i..=i]);
        assert_eq!(joined[i], alone[0], "slot {i} perturbed by a mid-flight join");
    }
}

#[test]
fn single_slot_forward_batch_is_bitwise_forward_token_on_owned_backends() {
    // The B=1 degenerate pin: owned backends execute the identical
    // per-row kernel on both entry points, so logits must be equal to
    // the last bit, step after step.
    let w = tiny_weights();
    for backend in [Backend::Standard, Backend::RsrPlusPlus] {
        let mut a = Transformer::from_weights(&w, backend, 0).unwrap();
        let mut b = Transformer::from_weights(&w, backend, 0).unwrap();
        b.ensure_slots(3); // spare slots must not change slot-0 math
        for (step, &t) in [7u32, 8, 9, 250].iter().enumerate() {
            let la = a.forward_token(t).unwrap().to_vec();
            let lb = b.forward_batch(&[t], &[0]).unwrap().to_vec();
            assert_eq!(la, lb, "{} step {step}", backend.name());
        }
        assert_eq!(a.seq_len(), b.seq_len_slot(0));
    }
}

#[test]
fn continuous_engine_matches_one_at_a_time_engine_exactly() {
    // Engine-level ragged + mid-flight check. Both runs use the
    // continuous engine (max_slots > 1 → batched kernel at every live
    // count), so batch-size invariance makes this assert_eq-exact:
    // staggered concurrent submissions vs strictly one-at-a-time.
    let weights = Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x77).unwrap());
    let reqs: Vec<(u64, Vec<u32>, usize)> = vec![
        (1, vec![5, 6, 7], 12),
        (2, vec![10, 20], 4),
        (3, vec![30, 31, 32, 33], 8),
        (4, vec![40], 16),
    ];
    let run = |concurrent: bool| -> Vec<(u64, Vec<u32>)> {
        let engine = InferenceEngine::start(
            Arc::clone(&weights),
            EngineConfig {
                workers: 1,
                batch: BatchPolicy { max_slots: 3, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        let mut out = Vec::new();
        if concurrent {
            // Gaps between submissions so later requests join decodes
            // already in flight (and, with 4 requests on 3 slots, one
            // joins only after a retirement frees its slot).
            for (id, p, m) in &reqs {
                engine.submit(Request::new(*id, p.clone(), *m)).unwrap();
                std::thread::sleep(Duration::from_millis(3));
            }
            for _ in 0..reqs.len() {
                let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
                assert!(r.error.is_none(), "{:?}", r.error);
                out.push((r.id, r.tokens));
            }
        } else {
            for (id, p, m) in &reqs {
                engine.submit(Request::new(*id, p.clone(), *m)).unwrap();
                let r = engine.recv_timeout(Duration::from_secs(60)).expect("response");
                assert!(r.error.is_none(), "{:?}", r.error);
                out.push((r.id, r.tokens));
            }
        }
        engine.shutdown();
        out.sort_by_key(|(id, _)| *id);
        out
    };
    assert_eq!(run(true), run(false), "mid-flight joins must not change tokens");
}
