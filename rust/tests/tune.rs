//! Integration: the tuning subsystem's compile-once/serve-many
//! contract (mirrors `tests/artifact.rs` for `.rsrz`).
//!
//! A `.rsrt` profile must round-trip exactly, reject truncation /
//! bit flips / unknown versions / foreign machine fingerprints with
//! distinct errors, and — the core safety property — a profile-driven
//! [`PlanStore`] must produce **bit-identical** multiply results to the
//! untuned store for every backend the profile can select (exercised on
//! integer-valued activations, where all f32 sums are exact, so any
//! divergence is an indexing bug rather than rounding).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use rsr::model::bitlinear::BitLinear;
use rsr::model::config::ModelConfig;
use rsr::model::sampler::Sampler;
use rsr::model::transformer::Transformer;
use rsr::model::weights::ModelWeights;
use rsr::runtime::PlanStore;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::request::Request;
use rsr::tune::{
    tune_model, LayerChoice, LayerProfile, MachineFingerprint, TuneOpts, TuneProfile,
    TunedBackend,
};
use rsr::util::rng::Rng;

/// Fresh per-test temp dir (no tempfile crate offline).
fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("rsr-tune-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_weights(seed: u64) -> ModelWeights {
    ModelWeights::generate(ModelConfig::tiny(), seed).unwrap()
}

/// A profile forcing one `(backend, k)` on every layer of `weights`.
fn forced_profile(weights: &ModelWeights, backend: TunedBackend, k: usize) -> TuneProfile {
    let layers = weights
        .named_matrices()
        .into_iter()
        .map(|(name, m, _scale)| LayerProfile {
            name,
            rows: m.rows(),
            cols: m.cols(),
            chain: vec![LayerChoice { backend, k, ns: 1.0 }],
        })
        .collect();
    TuneProfile::new(MachineFingerprint::current(), layers).unwrap()
}

#[test]
fn rsrt_file_round_trips_exactly() {
    let weights = tiny_weights(21);
    let (profile, _) = tune_model(
        &weights,
        &TuneOpts { radius: 0, budget_per_layer: Duration::from_millis(2), trials: 1 },
        |_| {},
    )
    .unwrap();
    let dir = temp_dir("roundtrip");
    let path = dir.join("tiny.rsrt");
    profile.save(&path).unwrap();
    let back = TuneProfile::load(&path).unwrap();
    assert_eq!(back, profile);
    back.verify_host().unwrap();
    assert_eq!(back.len(), weights.matrix_names().len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_rsrt_files_are_rejected() {
    let profile = forced_profile(&tiny_weights(23), TunedBackend::RsrPlusPlus, 4);
    let mut buf = Vec::new();
    profile.write_to(&mut buf).unwrap();

    // Round-trips clean.
    assert_eq!(TuneProfile::read_from(&mut buf.as_slice()).unwrap(), profile);

    // Truncation at any point.
    for cut in [4usize, 20, 35, buf.len() / 2, buf.len() - 1] {
        assert!(TuneProfile::read_from(&mut &buf[..cut]).is_err(), "cut {cut}");
    }
    // Body bit flip → checksum.
    let mut bad = buf.clone();
    let last = bad.len() - 5;
    bad[last] ^= 0x08;
    let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");
    // Header bit flip (fingerprint features, offset 8) → checksum.
    let mut bad = buf.clone();
    bad[8] ^= 0x01;
    let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");
    // Unknown version (offset 4) → distinct version error.
    let mut bad = buf.clone();
    bad[4..8].copy_from_slice(&99u32.to_le_bytes());
    let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
    assert!(err.to_string().contains("version 99"), "{err}");
    // Bad magic.
    let mut bad = buf;
    bad[1] ^= 0xFF;
    let err = TuneProfile::read_from(&mut bad.as_slice()).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");
}

#[test]
fn foreign_fingerprint_fails_distinctly_through_the_whole_stack() {
    let weights = tiny_weights(29);
    let mut profile = forced_profile(&weights, TunedBackend::RsrPlusPlus, 4);
    profile.fingerprint.threads += 7;
    let dir = temp_dir("foreign");
    let path = dir.join("foreign.rsrt");
    profile.save(&path).unwrap();

    // The file itself is valid — inspect-style loading succeeds…
    let back = TuneProfile::load(&path).unwrap();
    // …host verification fails with the machine error, not a format one.
    let err = back.verify_host().unwrap_err();
    assert!(err.to_string().contains("different machine"), "{err}");
    assert!(!err.to_string().contains("checksum"), "{err}");

    // PlanStore::with_profile refuses it.
    let store = PlanStore::for_model(Arc::new(weights.clone()), 0);
    assert!(store.with_profile(back).is_err());

    // And the engine refuses it at startup.
    let res = InferenceEngine::start(
        Arc::new(weights),
        EngineConfig { workers: 1, tune_profile: Some(path), ..Default::default() },
    );
    let err = match res {
        Err(e) => e,
        Ok(_) => panic!("foreign profile must fail engine startup"),
    };
    assert!(err.to_string().contains("different machine"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance-criteria determinism test: for every backend the
/// profile can select, a profile-driven store's layers multiply
/// bit-identically to the untuned store's layers.
#[test]
fn profile_driven_store_is_bit_identical_across_all_backends() {
    let weights = Arc::new(tiny_weights(31));
    let untuned = PlanStore::for_model(Arc::clone(&weights), 0);
    let mut rng = Rng::new(32);

    // Reference outputs from the untuned store, on integer activations
    // (exact f32 arithmetic → backend choice cannot change results).
    let sample_layers = ["layer0.wq", "layer1.gate", "layer1.down", "lm_head"];
    let mut inputs = Vec::new();
    let mut expected = Vec::new();
    for name in sample_layers {
        let entry = untuned.get(name).unwrap();
        let (rows, cols) = entry.shape();
        let x = rng.int_f32_vec(rows, 2);
        let mut layer = BitLinear::from_plan_entry(&entry, 1.0).unwrap();
        let mut out = vec![0.0f32; cols];
        layer.forward(&x, &mut out).unwrap();
        inputs.push(x);
        expected.push(out);
    }

    for backend in TunedBackend::ALL.into_iter().filter(|b| b.available()) {
        // One forced k for every layer (untuned layers pick their own
        // analytic k) — on exact integer arithmetic neither the
        // blocking nor the backend may change a single bit.
        let store = PlanStore::for_model(Arc::clone(&weights), 0)
            .with_profile(forced_profile(
                &weights,
                backend,
                rsr::kernels::optimal_k::optimal_k_rsrpp(weights.config.d_model),
            ))
            .unwrap();
        for (i, name) in sample_layers.iter().enumerate() {
            let entry = store.get(name).unwrap();
            assert_eq!(entry.tuned.unwrap().backend, backend);
            let mut layer = BitLinear::from_plan_entry(&entry, 1.0).unwrap();
            let mut out = vec![0.0f32; expected[i].len()];
            layer.forward(&inputs[i], &mut out).unwrap();
            assert_eq!(out, expected[i], "{name} via {}", backend.name());
        }
    }
}

#[test]
fn tuned_transformer_generates_identical_tokens() {
    // End to end at the model level: a store whose profile selects the
    // default backend at the default k is bit-identical to the untuned
    // store, so greedy decoding must match token for token. (Other
    // backends differ only by f32 re-association; the multiply-level
    // test above pins them exactly on integer inputs.)
    let weights = tiny_weights(37);
    let k = rsr::kernels::optimal_k::optimal_k_rsrpp(weights.config.d_model);
    let untuned_store = PlanStore::for_model(Arc::new(weights.clone()), 0);
    let tuned_store = PlanStore::for_model(Arc::new(weights.clone()), 0)
        .with_profile(forced_profile(&weights, TunedBackend::RsrPlusPlus, k))
        .unwrap();

    let mut a = Transformer::from_plan_store(&weights, &untuned_store).unwrap();
    let mut b = Transformer::from_plan_store(&weights, &tuned_store).unwrap();
    let prompt = [5u32, 6, 7, 8];
    let mut rng = Rng::new(3);
    let ta = a.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
    let mut rng = Rng::new(3);
    let tb = b.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
    assert_eq!(ta, tb);

    // The parallel-tuned model also produces identical tokens: its
    // per-block arithmetic is the same fold, just fanned across lanes.
    let par_store = PlanStore::for_model(Arc::new(weights.clone()), 0)
        .with_profile(forced_profile(&weights, TunedBackend::Parallel, k))
        .unwrap();
    let mut c = Transformer::from_plan_store(&weights, &par_store).unwrap();
    let mut rng = Rng::new(3);
    let tc = c.generate(&prompt, 6, Sampler::Greedy, &mut rng).unwrap();
    assert_eq!(ta, tc);
}

#[test]
fn profile_with_foreign_layer_geometry_is_rejected() {
    // Same layer names, different matrix shape (a different checkpoint
    // config): the profile's measurements do not apply and the store
    // must say so instead of silently tuning the wrong matrix.
    let weights = tiny_weights(47);
    let mut profile = forced_profile(&weights, TunedBackend::RsrPlusPlus, 4);
    profile.layers[0].rows += 1;
    assert_eq!(profile.layers[0].name, "layer0.wq");
    let store = PlanStore::for_model(Arc::new(weights), 0)
        .with_profile(profile)
        .unwrap();
    let err = store.get("layer0.wq").unwrap_err();
    assert!(err.to_string().contains("re-run `rsr tune`"), "{err}");
    // Untouched layers still build.
    store.get("layer0.wk").unwrap();
}

#[test]
fn artifact_backed_store_rejects_profile_with_mismatched_k() {
    use rsr::kernels::artifact::{ternary_fingerprint, PlanArtifact};
    use rsr::kernels::index::TernaryRsrIndex;

    let weights = tiny_weights(41);
    let dir = temp_dir("kmismatch");
    // Pack everything at k=4…
    for (name, m, scale) in weights.named_matrices() {
        PlanArtifact::ternary(name.clone(), TernaryRsrIndex::preprocess(m, 4), scale)
            .unwrap()
            .with_weights_fingerprint(ternary_fingerprint(m))
            .save(dir.join(format!("{name}.rsrz")))
            .unwrap();
    }
    // …and tune to k=3: selection cannot re-block a packed artifact.
    let store = PlanStore::open(&dir)
        .unwrap()
        .with_profile(forced_profile(&weights, TunedBackend::Rsr, 3))
        .unwrap();
    let err = store.get("layer0.wq").unwrap_err();
    assert!(err.to_string().contains("rsr pack --model"), "{err}");

    // Matching k works and carries the tuned backend through.
    let store = PlanStore::open(&dir)
        .unwrap()
        .with_profile(forced_profile(&weights, TunedBackend::Rsr, 4))
        .unwrap();
    let entry = store.get("layer0.wq").unwrap();
    assert_eq!(entry.tuned.unwrap().backend, TunedBackend::Rsr);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tune_then_serve_end_to_end() {
    // The CLI contract as a library flow: tune a tiny model on a small
    // budget, write the .rsrt, start an engine with it, serve a request.
    let weights = Arc::new(tiny_weights(43));
    let (profile, reports) = tune_model(
        &weights,
        &TuneOpts { radius: 1, budget_per_layer: Duration::from_millis(3), trials: 2 },
        |_| {},
    )
    .unwrap();
    assert_eq!(reports.len(), weights.matrix_names().len());
    let dir = temp_dir("serve");
    let path = dir.join("tiny.rsrt");
    profile.save(&path).unwrap();

    let engine = InferenceEngine::start(
        Arc::clone(&weights),
        EngineConfig { workers: 2, tune_profile: Some(path), ..Default::default() },
    )
    .unwrap();
    engine.submit(Request::new(1, vec![10, 20, 30], 4)).unwrap();
    let resp = engine.recv_timeout(Duration::from_secs(30)).expect("response");
    assert_eq!(resp.id, 1);
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert!(!resp.tokens.is_empty());
    engine.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
