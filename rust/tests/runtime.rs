//! Integration: the rust runtime executing real AOT artifacts — the
//! full three-layer path (Pallas kernel → JAX lowering → HLO text →
//! PJRT CPU execution from rust).
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use rsr::kernels::standard::dense_mul_f32;
use rsr::kernels::tensorized::TensorizedIndex;
use rsr::kernels::BinaryMatrix;
use rsr::runtime::{Engine, Tensor};
use rsr::util::rng::Rng;

fn engine() -> Option<Engine> {
    if !rsr::runtime::pjrt_enabled() {
        eprintln!("skipping runtime tests: built without the `pjrt` feature");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Engine::load(&dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(engine) = engine() else { return };
    let names = engine.names();
    assert!(names.iter().any(|n| n.starts_with("dense_matvec_n")));
    assert!(names.iter().any(|n| n.starts_with("rsr_matvec_")));
    assert!(names.iter().any(|n| n.starts_with("ffn_dense_")));
}

#[test]
fn dense_matvec_artifact_matches_rust_reference() {
    let Some(engine) = engine() else { return };
    let n = 1024;
    let mut rng = Rng::new(2024);
    let v = rng.f32_vec(n, -1.0, 1.0);
    let w = rng.f32_vec(n * n, -0.1, 0.1);
    let got = engine
        .run_f32(
            "dense_matvec_n1024",
            &[Tensor::F32(v.clone(), vec![n]), Tensor::F32(w.clone(), vec![n, n])],
        )
        .expect("execute");
    let expect = dense_mul_f32(&v, &w, n, n);
    assert_eq!(got.len(), n);
    for (g, e) in got.iter().zip(expect.iter()) {
        assert!((g - e).abs() < 1e-2 * (1.0 + e.abs()), "{g} vs {e}");
    }
}

#[test]
fn rsr_pallas_artifact_runs_with_rust_computed_keys() {
    // The paper's preprocessing done in RUST feeds the Pallas kernel
    // lowered from python — the cross-layer integration check.
    let Some(engine) = engine() else { return };
    let (n, k) = (1024usize, 8usize);
    let mut rng = Rng::new(777);
    let b = BinaryMatrix::random(n, n, 0.5, &mut rng);
    let v = rng.f32_vec(n, -1.0, 1.0);

    // Rust-side preprocessing → block keys (the M-matrix one-hot form).
    let tens = TensorizedIndex::preprocess(&b, k);
    let nb = n / k;
    let mut keys = vec![0i32; nb * n];
    for (bi, ks) in tens.keys.iter().enumerate() {
        for (r, &key) in ks.iter().enumerate() {
            keys[bi * n + r] = key as i32;
        }
    }
    // Bin_[k] matrix.
    let bin = rsr::kernels::index::BinMatrix::new(k);
    let binm: Vec<f32> = bin.to_dense().iter().map(|&x| x as f32).collect();

    let got = engine
        .run_f32(
            &format!("rsr_matvec_n{n}_k{k}"),
            &[
                Tensor::F32(v.clone(), vec![n]),
                Tensor::I32(keys, vec![nb, n]),
                Tensor::F32(binm, vec![1 << k, k]),
            ],
        )
        .expect("execute rsr artifact");

    let expect = rsr::kernels::standard::standard_mul_binary(&v, &b);
    assert_eq!(got.len(), n);
    for (i, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
        assert!((g - e).abs() < 1e-2 * (1.0 + e.abs()), "col {i}: {g} vs {e}");
    }
}

#[test]
fn ffn_artifact_matches_rust_swiglu() {
    let Some(engine) = engine() else { return };
    let (d, ff) = (1024usize, 4096usize);
    let mut rng = Rng::new(31337);
    let x = rng.f32_vec(d, -1.0, 1.0);
    let scale = 1.0 / (d as f32).sqrt();
    let wg = rng.f32_vec(d * ff, -scale, scale);
    let wu = rng.f32_vec(d * ff, -scale, scale);
    let wd = rng.f32_vec(ff * d, -scale, scale);
    let got = engine
        .run_f32(
            &format!("ffn_dense_d{d}_ff{ff}"),
            &[
                Tensor::F32(x.clone(), vec![d]),
                Tensor::F32(wg.clone(), vec![d, ff]),
                Tensor::F32(wu.clone(), vec![d, ff]),
                Tensor::F32(wd.clone(), vec![ff, d]),
            ],
        )
        .expect("execute ffn");
    // Rust reference.
    let g = dense_mul_f32(&x, &wg, d, ff);
    let u = dense_mul_f32(&x, &wu, d, ff);
    let h: Vec<f32> = g
        .iter()
        .zip(u.iter())
        .map(|(&g, &u)| (g / (1.0 + (-g).exp())) * u)
        .collect();
    let expect = dense_mul_f32(&h, &wd, ff, d);
    for (g, e) in got.iter().zip(expect.iter()) {
        assert!((g - e).abs() < 1e-2 * (1.0 + e.abs()), "{g} vs {e}");
    }
}

#[test]
fn ffn_rsr_artifact_composes_l1_kernel_three_times() {
    // The deepest cross-layer check: a SwiGLU block whose three
    // projections each run the Pallas RSR kernel (L2 calling L1),
    // executed from rust (L3) with rust-computed keys, compared to a
    // rust-side dense reference.
    let Some(engine) = engine() else { return };
    let (d, ff, k) = (256usize, 512usize, 4usize);
    let name = format!("ffn_rsr_d{d}_ff{ff}_k{k}");
    if engine.spec(&name).is_none() {
        eprintln!("skipping: artifact {name} absent (older manifest)");
        return;
    }
    let mut rng = Rng::new(0xFF9);
    let wg = BinaryMatrix::random(d, ff, 0.5, &mut rng);
    let wu = BinaryMatrix::random(d, ff, 0.5, &mut rng);
    let wd = BinaryMatrix::random(ff, d, 0.5, &mut rng);
    let x = rng.f32_vec(d, -0.2, 0.2);

    let keys_of = |b: &BinaryMatrix| -> Vec<i32> {
        let t = TensorizedIndex::preprocess(b, k);
        let mut out = vec![0i32; t.keys.len() * b.rows()];
        for (bi, ks) in t.keys.iter().enumerate() {
            for (r, &key) in ks.iter().enumerate() {
                out[bi * b.rows() + r] = key as i32;
            }
        }
        out
    };
    let bin = rsr::kernels::index::BinMatrix::new(k);
    let binm: Vec<f32> = bin.to_dense().iter().map(|&v| v as f32).collect();

    let got = engine
        .run_f32(
            &name,
            &[
                Tensor::F32(x.clone(), vec![d]),
                Tensor::I32(keys_of(&wg), vec![ff / k, d]),
                Tensor::I32(keys_of(&wu), vec![ff / k, d]),
                Tensor::I32(keys_of(&wd), vec![d / k, ff]),
                Tensor::F32(binm, vec![1 << k, k]),
            ],
        )
        .expect("execute ffn_rsr");

    // Dense rust reference of the same block.
    let to_f32 = |b: &BinaryMatrix| -> Vec<f32> {
        b.to_dense().iter().map(|&v| v as f32).collect()
    };
    let g = dense_mul_f32(&x, &to_f32(&wg), d, ff);
    let u = dense_mul_f32(&x, &to_f32(&wu), d, ff);
    let h: Vec<f32> = g
        .iter()
        .zip(u.iter())
        .map(|(&g, &u)| (g / (1.0 + (-g).exp())) * u)
        .collect();
    let expect = dense_mul_f32(&h, &to_f32(&wd), ff, d);
    assert_eq!(got.len(), d);
    for (i, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
        assert!((g - e).abs() < 1e-2 * (1.0 + e.abs()), "elem {i}: {g} vs {e}");
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    // Wrong arity.
    assert!(engine
        .run_f32("dense_matvec_n1024", &[Tensor::F32(vec![0.0; 1024], vec![1024])])
        .is_err());
    // Wrong shape.
    assert!(engine
        .run_f32(
            "dense_matvec_n1024",
            &[
                Tensor::F32(vec![0.0; 512], vec![512]),
                Tensor::F32(vec![0.0; 1024 * 1024], vec![1024, 1024]),
            ],
        )
        .is_err());
    // Unknown artifact.
    assert!(engine.run_f32("nope", &[]).is_err());
}
