//! Wire protocol v2 streaming integration: token-frame reassembly,
//! interleaved streaming/non-streaming clients, mid-stream disconnect
//! accounting, drain semantics over the wire, and the v1 shape pin.
//!
//! Terminal-outcome assertions use the typed [`ErrorCode`] surface —
//! never error prose, which carries no stability promise.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::client::{Client, ErrorCode};
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::router::Router;
use rsr::serving::server::{ResponseHub, Server};
use rsr::util::json::Json;

/// A model big enough that decoding ~200 tokens takes a few hundred
/// milliseconds — the window the disconnect and drain tests act in.
fn slow_config() -> ModelConfig {
    ModelConfig {
        name: "streaming-slow".into(),
        vocab_size: 270,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 4,
        d_ff: 512,
        max_seq_len: 256,
        rope_theta: 10_000.0,
    }
}

struct TestServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    engines: Vec<Arc<InferenceEngine>>,
    hub: Arc<ResponseHub>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(cfg: ModelConfig, replicas: usize, workers: usize) -> Self {
        let weights = Arc::new(ModelWeights::generate(cfg, 0x5712).unwrap());
        let engines: Vec<Arc<InferenceEngine>> = (0..replicas)
            .map(|_| {
                Arc::new(
                    InferenceEngine::start(
                        Arc::clone(&weights),
                        EngineConfig {
                            workers,
                            backend: Backend::RsrPlusPlus,
                            ..Default::default()
                        },
                    )
                    .unwrap(),
                )
            })
            .collect();
        let router = Arc::new(Router::new(engines.clone()).unwrap());
        let server = Server::new(router);
        let hub = Arc::clone(server.hub());
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
        let bound2 = Arc::clone(&bound);
        let thread = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", stop2, move |a| {
                    *bound2.lock().unwrap() = Some(a);
                })
                .unwrap();
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        Self { addr, stop, engines, hub, thread: Some(thread) }
    }

    /// Wait (bounded) for the serve loop to return on its own — the
    /// drain exit path. Panics if it is still running at the deadline.
    fn join_within(mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        let thread = self.thread.take().unwrap();
        while !thread.is_finished() {
            assert!(
                Instant::now() < deadline,
                "server did not exit within {timeout:?} after drain"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        thread.join().unwrap();
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Drive one streaming request and return (concatenated frame text,
/// frame token ids, terminal outcome).
fn run_streamed(
    client: &mut Client,
    id: u64,
    prompt: &str,
    max_new: usize,
) -> (String, Vec<u32>, rsr::serving::client::Outcome) {
    let mut text = String::new();
    let mut tokens: Vec<u32> = Vec::new();
    let mut next_index = 0u64;
    let out = client
        .prompt(id, prompt)
        .max_new(max_new)
        .stream_with(|frame| {
            if let Some(t) = frame.get("text").and_then(|t| t.as_str()) {
                text.push_str(t);
            }
            // The flush frame carries text only; real token frames
            // carry a contiguous index and the sampled token id.
            if let Some(tok) = frame.get("token").and_then(|t| t.as_f64()) {
                tokens.push(tok as u32);
                let idx = frame.get("index").and_then(|i| i.as_f64()).unwrap();
                assert_eq!(idx as u64, next_index, "token frames must be in order");
                next_index += 1;
            }
        })
        .unwrap();
    (text, tokens, out)
}

#[test]
fn streamed_concatenation_is_byte_identical_to_non_streaming() {
    let server = TestServer::start(ModelConfig::tiny(), 1, 1);
    let mut client = Client::connect(server.addr).unwrap();
    let prompt = "What is the capital of France?";

    let (text, tokens, out) = run_streamed(&mut client, 7, prompt, 6);
    assert!(out.is_ok(), "{:?}", out.error);
    assert!(!tokens.is_empty() && tokens.len() <= 6);
    // Reassembly: the frames carry exactly the done frame's payload.
    assert_eq!(text, out.text, "concatenated frame text != done text");
    assert_eq!(tokens, out.tokens, "frame token ids != done tokens");

    // Greedy decode is deterministic: a non-streaming request for the
    // same prompt must produce the identical completion.
    let plain = client.prompt(8, prompt).max_new(6).send().unwrap();
    assert!(plain.is_ok(), "{:?}", plain.error);
    assert_eq!(plain.text, text, "streamed reassembly != non-streaming completion");
    assert_eq!(plain.tokens, tokens);
}

#[test]
fn streaming_and_plain_clients_interleave() {
    let server = TestServer::start(ModelConfig::tiny(), 1, 2);
    let addr = server.addr;
    let streamer = std::thread::spawn(move || {
        let mut client = Client::connect(addr).unwrap();
        for i in 0..3u64 {
            let (text, tokens, out) =
                run_streamed(&mut client, i, "Name a planet, slowly.", 4);
            assert!(out.is_ok(), "{:?}", out.error);
            assert_eq!(text, out.text);
            assert_eq!(tokens, out.tokens);
        }
    });
    let plain = std::thread::spawn(move || {
        let mut client = Client::connect(addr).unwrap();
        for i in 0..3u64 {
            let out = client.prompt(i, "Name a river.").max_new(4).send().unwrap();
            assert!(out.is_ok(), "{:?}", out.error);
            assert!(!out.tokens.is_empty());
        }
    });
    streamer.join().unwrap();
    plain.join().unwrap();
}

#[test]
fn mid_stream_disconnect_frees_the_slot() {
    let server = TestServer::start(slow_config(), 1, 1);
    let engine = Arc::clone(&server.engines[0]);
    {
        // Raw socket: start a long stream, read two frames, vanish.
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        writeln!(
            writer,
            r#"{{"id": 9, "prompt": "stream then vanish mid-flight", "max_new": 200, "stream": true}}"#
        )
        .unwrap();
        let mut line = String::new();
        for _ in 0..2 {
            line.clear();
            reader.read_line(&mut line).unwrap();
            assert!(
                line.contains(r#""event""#),
                "expected a streaming frame, got: {line}"
            );
        }
        // Drop both halves: the server's next disconnect poll cancels
        // the request and the engine retires the slot within a step.
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while engine.live_slots() > 0 || engine.inflight() > 0 || server.hub.waiter_count() > 0
    {
        assert!(
            Instant::now() < deadline,
            "slot/waiter not freed after mid-stream disconnect: \
             live_slots={} inflight={} waiters={}",
            engine.live_slots(),
            engine.inflight(),
            server.hub.waiter_count()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // Conservation: the cancelled request still reached exactly one
    // terminal outcome.
    let snap = engine.snapshot();
    assert!(matches!(snap.get("conserved"), Some(Json::Bool(true))));
}

#[test]
fn drain_finishes_streams_and_refuses_new_with_code() {
    let server = TestServer::start(slow_config(), 1, 1);
    let addr = server.addr;
    let engine = Arc::clone(&server.engines[0]);

    // A long in-flight stream: the drain must let it run to completion.
    let streamer = std::thread::spawn(move || {
        let mut client = Client::connect(addr).unwrap();
        run_streamed(&mut client, 1, "please stream this long answer", 200)
    });
    let deadline = Instant::now() + Duration::from_secs(30);
    while engine.live_slots() == 0 && engine.inflight() == 0 {
        assert!(Instant::now() < deadline, "stream never became in-flight");
        std::thread::sleep(Duration::from_millis(5));
    }

    let mut control = Client::connect(addr).unwrap();
    let reply = control.control("drain").unwrap();
    assert!(matches!(reply.get("draining"), Some(Json::Bool(true))), "{}", reply.to_string());

    // New work is refused with the stable code, not prose.
    let refused = control.prompt(2, "too late").max_new(4).send().unwrap();
    assert_eq!(refused.code(), Some(ErrorCode::Draining), "{:?}", refused.error);

    // The in-flight stream still completes in full.
    let (text, tokens, out) = streamer.join().unwrap();
    assert!(out.is_ok(), "{:?}", out.error);
    assert_eq!(text, out.text);
    assert_eq!(tokens, out.tokens);

    // serve() exits on its own once every replica is drained …
    server.join_within(Duration::from_secs(30));
    // … with nothing in flight and the books balanced.
    assert!(engine.drained());
    assert_eq!(engine.inflight(), 0);
    let snap = engine.snapshot();
    assert!(matches!(snap.get("conserved"), Some(Json::Bool(true))));
    assert!(matches!(snap.get("draining"), Some(Json::Bool(true))));
}

/// The sorted key set of a reply object (the wire uses sorted-key
/// JSON, so this is also the on-wire field order).
fn keys(reply: &Json) -> Vec<String> {
    match reply {
        Json::Obj(map) => map.keys().cloned().collect(),
        other => panic!("expected an object, got {other:?}"),
    }
}

#[test]
fn v1_reply_shape_is_pinned() {
    let server = TestServer::start(ModelConfig::tiny(), 1, 1);
    let mut client = Client::connect(server.addr).unwrap();

    // Success line: exactly the v1 fields — no `event`, no `code`.
    let reply = client
        .send_raw(r#"{"id": 5, "prompt": "hi there", "max_new": 2}"#)
        .unwrap();
    assert_eq!(
        keys(&reply),
        ["decode_us", "id", "prefill_us", "queue_us", "text", "tokens"],
        "v1 success line shape changed: {reply}",
        reply = reply.to_string()
    );

    // Error lines gain exactly one additive v2 field: `code`.
    let reply = client.send_raw(r#"{"id": 5}"#).unwrap();
    assert_eq!(keys(&reply), ["code", "error"]);
    assert_eq!(
        reply.get("code").and_then(|c| c.as_str()).map(ErrorCode::from_wire),
        Some(ErrorCode::BadRequest)
    );
    let reply = client
        .send_raw(r#"{"id": 5, "prompt": "hi", "max_new": 100000}"#)
        .unwrap();
    assert_eq!(keys(&reply), ["code", "error"]);
    assert_eq!(
        reply.get("code").and_then(|c| c.as_str()).map(ErrorCode::from_wire),
        Some(ErrorCode::BadRequest)
    );

    // The connection still serves a good v1 request afterwards.
    let out = client.prompt(6, "still alive?").max_new(2).send().unwrap();
    assert!(out.is_ok());
}
