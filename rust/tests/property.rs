//! Property-based tests over the paper's invariants, built on the
//! in-tree deterministic PRNG (the offline registry has no proptest;
//! see DESIGN.md §Substitutions). Every case prints its seed on
//! failure so it can be replayed exactly.

use rsr::kernels::blocking::column_blocks;
use rsr::kernels::index::{BinMatrix, RsrIndex, TernaryRsrIndex};
use rsr::kernels::permutation::is_permutation;
use rsr::kernels::qbit::QbitMatrix;
use rsr::kernels::rsr::rsr_mul;
use rsr::kernels::rsrpp::rsrpp_mul;
use rsr::kernels::standard::{standard_mul_binary, standard_mul_ternary};
use rsr::kernels::tensorized::TensorizedIndex;
use rsr::kernels::{BinaryMatrix, TernaryMatrix};
use rsr::util::rng::Rng;

const CASES: usize = 60;

/// Deterministic per-case generator: (n, m, k, density, case seed).
fn case_params(master: &mut Rng) -> (usize, usize, usize, f64, u64) {
    let n = master.range(1, 200);
    let m = master.range(1, 150);
    let k = master.range(1, 11);
    let density = master.next_f64();
    let seed = master.next_u64();
    (n, m, k, density, seed)
}

#[test]
fn prop_rsr_equals_rsrpp_equals_standard() {
    let mut master = Rng::new(0xBEEF);
    for case in 0..CASES {
        let (n, m, k, density, seed) = case_params(&mut master);
        let mut rng = Rng::new(seed);
        let b = BinaryMatrix::random(n, m, density, &mut rng);
        // Integer-valued activations: f32 sums are exact for these
        // magnitudes, so all reorderings must agree bit-for-bit.
        let v = rng.int_f32_vec(n, 8);
        let expect = standard_mul_binary(&v, &b);
        let got_rsr = rsr_mul(&v, &b, k);
        let got_pp = rsrpp_mul(&v, &b, k);
        assert_eq!(got_rsr, expect, "case {case} seed {seed} (n={n},m={m},k={k})");
        assert_eq!(got_pp, expect, "case {case} seed {seed} (n={n},m={m},k={k})");
    }
}

#[test]
fn prop_preprocessing_invariants() {
    let mut master = Rng::new(0xCAFE);
    for case in 0..CASES {
        let (n, m, k, density, seed) = case_params(&mut master);
        let mut rng = Rng::new(seed);
        let b = BinaryMatrix::random(n, m, density, &mut rng);
        let idx = RsrIndex::preprocess(&b, k);
        idx.validate().unwrap_or_else(|e| panic!("case {case} seed {seed}: {e}"));
        // Blocks tile the columns.
        assert_eq!(idx.blocks.len(), m.div_ceil(k), "case {case}");
        for blk in &idx.blocks {
            // σ is a bijection (also checked by validate; assert the
            // helper directly for coverage).
            assert!(is_permutation(&blk.sigma, n), "case {case} seed {seed}");
            // Sorted keys are non-decreasing and consistent with L:
            // every position's key equals the segment it falls in.
            for (pos, &r) in blk.sigma.iter().enumerate() {
                let key =
                    b.row_key(r as usize, blk.col_start as usize, blk.width as usize);
                let lo = blk.seg[key as usize] as usize;
                let hi = blk.seg[key as usize + 1] as usize;
                assert!(
                    (lo..hi).contains(&pos),
                    "case {case} seed {seed}: row {r} key {key} at pos {pos} not in [{lo},{hi})"
                );
            }
            // Prop 3.5: segment lengths sum to n.
            let total: u32 =
                (0..1usize << blk.width).map(|j| blk.seg[j + 1] - blk.seg[j]).sum();
            assert_eq!(total as usize, n, "case {case}");
        }
    }
}

#[test]
fn prop_index_serialization_roundtrip() {
    let mut master = Rng::new(0xD00D);
    for case in 0..30 {
        let (n, m, k, density, seed) = case_params(&mut master);
        let mut rng = Rng::new(seed);
        let b = BinaryMatrix::random(n.max(1), m.max(1), density, &mut rng);
        let idx = RsrIndex::preprocess(&b, k);
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        let back = RsrIndex::read_from(&mut buf.as_slice())
            .unwrap_or_else(|e| panic!("case {case} seed {seed}: {e}"));
        assert_eq!(idx, back, "case {case}");
    }
}

#[test]
fn prop_ternary_decomposition_reconstructs() {
    let mut master = Rng::new(0xE11E);
    for case in 0..CASES {
        let n = master.range(1, 80);
        let m = master.range(1, 80);
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let (p, mi) = a.decompose();
        for r in 0..n {
            for c in 0..m {
                assert_eq!(
                    p.get(r, c) as i8 - mi.get(r, c) as i8,
                    a.get(r, c),
                    "case {case} seed {seed} ({r},{c})"
                );
            }
        }
        // pack2 round-trip too.
        assert_eq!(TernaryMatrix::unpack2(n, m, &a.pack2()).unwrap(), a, "case {case}");
    }
}

#[test]
fn prop_ternary_rsr_equals_standard_exact_on_integers() {
    let mut master = Rng::new(0xF00D);
    for case in 0..CASES {
        let n = master.range(1, 120);
        let m = master.range(1, 100);
        let k = master.range(1, 9);
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let v = rng.int_f32_vec(n, 6);
        let expect = standard_mul_ternary(&v, &a);
        let mut plan = rsr::kernels::rsr::TernaryRsrPlan::new(
            TernaryRsrIndex::preprocess(&a, k),
        )
        .unwrap();
        let mut out = vec![0.0; m];
        plan.execute(&v, &mut out).unwrap();
        assert_eq!(out, expect, "case {case} seed {seed} (n={n},m={m},k={k})");
    }
}

#[test]
fn prop_tensorized_equals_gather_exact_on_integers() {
    let mut master = Rng::new(0x7E57);
    for case in 0..CASES {
        let (n, m, k, density, seed) = case_params(&mut master);
        let mut rng = Rng::new(seed);
        let b = BinaryMatrix::random(n, m, density, &mut rng);
        let v = rng.int_f32_vec(n, 8);
        let idx = TensorizedIndex::preprocess(&b, k);
        let mut out = vec![0.0; m];
        idx.execute(&v, &mut out).unwrap();
        // Note: scatter order differs from gather order; integer values
        // keep f32 addition exact so they must still be identical.
        assert_eq!(out, standard_mul_binary(&v, &b), "case {case} seed {seed}");
    }
}

#[test]
fn prop_qbit_planes_reconstruct() {
    let mut master = Rng::new(0x9B17);
    for case in 0..30 {
        let n = master.range(1, 40);
        let m = master.range(1, 40);
        let q = master.range(2, 9) as u32;
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let w = QbitMatrix::random(n, m, q, &mut rng);
        let planes = w.planes();
        assert_eq!(planes.len(), (q - 1) as usize);
        for r in 0..n {
            for c in 0..m {
                let recon: i32 = planes
                    .iter()
                    .map(|(b, p, mi)| {
                        (1i32 << b) * (p.get(r, c) as i32 - mi.get(r, c) as i32)
                    })
                    .sum();
                assert_eq!(recon, w.get(r, c), "case {case} seed {seed} q={q}");
            }
        }
    }
}

#[test]
fn prop_bin_matrix_rows_are_sorted_binary_values() {
    for k in 1..=10usize {
        let bin = BinMatrix::new(k);
        let mut prev = None;
        for l in 0..bin.rows() {
            let mut val = 0u32;
            for j in 0..k {
                val = (val << 1) | bin.get(l, j) as u32;
            }
            assert_eq!(val as usize, l, "Bin_[{k}] row {l} must encode {l}");
            if let Some(p) = prev {
                assert!(val > p);
            }
            prev = Some(val);
        }
    }
}

#[test]
fn prop_blocking_partitions_columns() {
    let mut master = Rng::new(0xB10C);
    for _ in 0..100 {
        let cols = master.range(1, 500);
        let k = master.range(1, 17);
        let blocks = column_blocks(cols, k);
        let mut covered = 0usize;
        for b in &blocks {
            assert_eq!(b.col_start, covered);
            assert!(b.width >= 1 && b.width <= k);
            covered += b.width;
        }
        assert_eq!(covered, cols);
        // Only the last block may be narrower than k.
        for b in &blocks[..blocks.len().saturating_sub(1)] {
            assert_eq!(b.width, k);
        }
    }
}

#[test]
fn prop_linearity_of_rsr() {
    // RSR is a linear operator: RSR(αu + βw, B) = αRSR(u,B) + βRSR(w,B).
    let mut master = Rng::new(0x11EA);
    for case in 0..20 {
        let (n, m, k, density, seed) = case_params(&mut master);
        let mut rng = Rng::new(seed);
        let b = BinaryMatrix::random(n, m, density, &mut rng);
        let u = rng.int_f32_vec(n, 4);
        let w = rng.int_f32_vec(n, 4);
        let (alpha, beta) = (2.0f32, -3.0f32);
        let combined: Vec<f32> =
            u.iter().zip(w.iter()).map(|(a, b)| alpha * a + beta * b).collect();
        let lhs = rsrpp_mul(&combined, &b, k);
        let ru = rsrpp_mul(&u, &b, k);
        let rw = rsrpp_mul(&w, &b, k);
        for i in 0..m {
            let rhs = alpha * ru[i] + beta * rw[i];
            assert!(
                (lhs[i] - rhs).abs() < 1e-3 * (1.0 + rhs.abs()),
                "case {case} seed {seed} elem {i}"
            );
        }
    }
}
