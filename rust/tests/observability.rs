//! Observability integration: scraping `metrics` / `status` / `trace`
//! over the wire from a live server, Prometheus exposition
//! well-formedness, trace slow-log capture of misbehaving requests,
//! and counter conservation under concurrent traffic.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use rsr::kernels::Backend;
use rsr::model::config::ModelConfig;
use rsr::model::weights::ModelWeights;
use rsr::serving::batcher::BatchPolicy;
use rsr::serving::engine::{EngineConfig, InferenceEngine};
use rsr::serving::router::Router;
use rsr::serving::client::Client;
use rsr::serving::server::{Server, ServerIdentity};
use rsr::util::json::Json;

fn tiny_weights() -> Arc<ModelWeights> {
    Arc::new(ModelWeights::generate(ModelConfig::tiny(), 0x0B5E).unwrap())
}

/// Like the `serving.rs` harness, but parameterized over the engine
/// config (to flip `trace_slow_ms` / `profile_layers`) and stamped
/// with a `ServerIdentity` so `status` has something to report.
struct TestServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl TestServer {
    fn start(replicas: usize, config: EngineConfig) -> Self {
        let weights = tiny_weights();
        let engines: Vec<Arc<InferenceEngine>> = (0..replicas)
            .map(|_| {
                Arc::new(
                    InferenceEngine::start(Arc::clone(&weights), config.clone())
                        .unwrap(),
                )
            })
            .collect();
        let router = Arc::new(Router::new(engines).unwrap());
        let server = Server::new(router).with_identity(ServerIdentity {
            model: "tiny".into(),
            plan_dir: None,
            tune_profile: Some("bench/tuned.rsrt".into()),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::default();
        let bound2 = Arc::clone(&bound);
        let thread = std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", stop2, move |a| {
                    *bound2.lock().unwrap() = Some(a);
                })
                .unwrap();
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        Self { addr, stop, thread: Some(thread) }
    }

    fn default_config() -> EngineConfig {
        EngineConfig { workers: 1, backend: Backend::RsrPlusPlus, ..Default::default() }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Parse one Prometheus sample line into (name, labels, value).
/// Returns `None` for comments and blank lines.
fn parse_sample(line: &str) -> Option<(String, Vec<(String, String)>, f64)> {
    if line.is_empty() || line.starts_with('#') {
        return None;
    }
    let (head, value) = line.rsplit_once(' ')?;
    let value: f64 = value.parse().ok()?;
    let (name, labels) = match head.split_once('{') {
        Some((n, rest)) => {
            let body = rest.strip_suffix('}')?;
            let labels = body
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|pair| {
                    let (k, v) = pair.split_once('=').unwrap();
                    (k.to_string(), v.trim_matches('"').to_string())
                })
                .collect();
            (n.to_string(), labels)
        }
        None => (head.to_string(), Vec::new()),
    };
    Some((name, labels, value))
}

fn scrape_prom(client: &mut Client) -> String {
    let reply = client.send_raw(r#"{"cmd": "metrics", "format": "prom"}"#).unwrap();
    assert!(reply.get("error").is_none(), "{}", reply.to_string());
    reply.get("prom").unwrap().as_str().unwrap().to_string()
}

#[test]
fn prometheus_exposition_is_well_formed() {
    let server = TestServer::start(1, TestServer::default_config());
    let mut client = Client::connect(server.addr).unwrap();
    for i in 0..3 {
        let reply = client
            .prompt(i, "Name a planet in the solar system.")
            .max_new(4)
            .send_json()
            .unwrap();
        assert!(reply.get("error").is_none(), "{}", reply.to_string());
    }
    let text = scrape_prom(&mut client);

    // Every sample family is announced.
    assert!(text.contains("# HELP rsr_requests_admitted_total "));
    assert!(text.contains("# TYPE rsr_requests_admitted_total counter"));
    assert!(text.contains("# TYPE rsr_ttft_us histogram"));
    assert!(text.contains("# TYPE rsr_queue_depth gauge"));
    // Memory governance rides the same scrape: page gauges and the
    // budget counters are always exposed (0 on an unbudgeted server).
    assert!(text.contains("# TYPE rsr_kv_pages_in_use gauge"));
    assert!(text.contains("# TYPE rsr_kv_pages_total gauge"));
    assert!(text.contains("# TYPE rsr_kv_reservations_failed_total counter"));
    assert!(text.contains("# TYPE rsr_kv_evictions_total counter"));
    assert!(text.contains("# TYPE rsr_requests_kv_budget_exceeded_total counter"));
    // Nothing non-finite leaks into the exposition.
    assert!(!text.contains("NaN") && !text.contains("inf "), "{text}");

    let samples: Vec<_> = text.lines().filter_map(parse_sample).collect();
    assert!(!samples.is_empty());

    // Counters carry the `_total` suffix and are announced as counters.
    // (`rsr_kv_pages_total` is the one deliberate exception: a gauge —
    // the page budget — named for parity with the `kv_pages_total`
    // snapshot key; it must still be announced, as a gauge.)
    for (name, _, v) in &samples {
        if name.ends_with("_total") {
            let expected = if name == "rsr_kv_pages_total" { "gauge" } else { "counter" };
            assert!(
                text.contains(&format!("# TYPE {name} {expected}")),
                "{name} missing `# TYPE {name} {expected}` line"
            );
            assert!(*v >= 0.0, "counter {name} negative: {v}");
        }
    }

    // Traffic actually registered.
    let admitted: f64 = samples
        .iter()
        .filter(|(n, _, _)| n == "rsr_requests_admitted_total")
        .map(|(_, _, v)| *v)
        .sum();
    assert!(admitted >= 3.0, "admitted={admitted}");

    // Histogram buckets: cumulative counts are monotone in `le` (the
    // renderer emits buckets in ascending order) and the +Inf bucket
    // equals `_count` for the same series.
    let mut bucket_series: std::collections::BTreeMap<String, Vec<(String, f64)>> =
        Default::default();
    for (name, labels, v) in &samples {
        if let Some(base) = name.strip_suffix("_bucket") {
            let le = labels.iter().find(|(k, _)| k == "le").unwrap().1.clone();
            let key: String = labels
                .iter()
                .filter(|(k, _)| k != "le")
                .map(|(k, val)| format!("{k}={val},"))
                .chain([base.to_string()])
                .collect();
            bucket_series.entry(key).or_default().push((le, *v));
        }
    }
    assert!(!bucket_series.is_empty(), "no histogram buckets rendered");
    for (key, buckets) in &bucket_series {
        let mut prev = 0.0;
        for (le, v) in buckets {
            assert!(*v >= prev, "{key}: bucket le={le} decreased ({v} < {prev})");
            prev = *v;
        }
        let (last_le, last_v) = buckets.last().unwrap();
        assert_eq!(last_le, "+Inf", "{key}: final bucket must be +Inf");
        let base = key.rsplit(',').next().unwrap();
        let count: f64 = samples
            .iter()
            .filter(|(n, labels, _)| {
                n == &format!("{base}_count")
                    && labels.iter().all(|(k, v)| {
                        k == "le" || key.contains(&format!("{k}={v},"))
                    })
            })
            .map(|(_, _, v)| *v)
            .sum();
        assert_eq!(
            *last_v, count,
            "{key}: +Inf bucket ({last_v}) != _count ({count})"
        );
    }
}

#[test]
fn metrics_json_scrape_reports_conserved_counters() {
    let server = TestServer::start(2, TestServer::default_config());
    let addr = server.addr;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                client.prompt(i, "Which ocean is the largest?").max_new(3).send_json().unwrap()
            })
        })
        .collect();
    // Scrape mid-traffic: the reply must parse and stay conserved even
    // while requests are inflight.
    let mut client = Client::connect(addr).unwrap();
    let mid = client.send_raw(r#"{"cmd": "metrics"}"#).unwrap();
    assert!(mid.get("error").is_none(), "{}", mid.to_string());
    for h in handles {
        let reply = h.join().unwrap();
        assert!(reply.get("error").is_none(), "{}", reply.to_string());
    }
    let reply = client.send_raw(r#"{"cmd": "metrics"}"#).unwrap();
    assert!(reply.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    let replicas = reply.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas.len(), 2);
    let mut admitted = 0.0;
    let mut completed = 0.0;
    for r in replicas {
        assert!(r.get("queue_depth").is_some() && r.get("live_slots").is_some());
        let m = r.get("metrics").unwrap();
        assert!(matches!(m.get("conserved"), Some(Json::Bool(true))), "{}", m.to_string());
        admitted += m.get("admitted").unwrap().as_f64().unwrap();
        completed += m.get("completed").unwrap().as_f64().unwrap();
    }
    assert_eq!(admitted, 6.0);
    assert_eq!(completed, 6.0);
}

#[test]
fn status_reports_identity_and_replica_gauges() {
    let server = TestServer::start(1, TestServer::default_config());
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client.send_raw(r#"{"cmd": "status"}"#).unwrap();
    assert_eq!(reply.get("model").unwrap().as_str(), Some("tiny"));
    assert_eq!(reply.get("plan_dir"), Some(&Json::Null));
    assert_eq!(reply.get("tune_profile").unwrap().as_str(), Some("bench/tuned.rsrt"));
    assert!(reply.get("uptime_s").unwrap().as_f64().is_some());
    let replicas = reply.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas.len(), 1);
    let r = &replicas[0];
    assert_eq!(r.get("replica").unwrap().as_f64(), Some(0.0));
    for key in
        ["queue_depth", "inflight", "live_slots", "heartbeat_ms", "kv_pages_in_use"]
    {
        assert!(r.get(key).unwrap().as_f64().is_some(), "missing gauge {key}");
    }
    // Unbudgeted server: the page ceiling gauge reads 0 (= no budget).
    assert_eq!(r.get("kv_pages_total").unwrap().as_f64(), Some(0.0));
    // Control lines don't poison the connection for inference.
    let reply = client.prompt(1, "still serving?").max_new(2).send_json().unwrap();
    assert!(reply.get("error").is_none());
}

#[test]
fn trace_command_reports_disabled_when_tracing_off() {
    let server = TestServer::start(1, TestServer::default_config());
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client.send_raw(r#"{"cmd": "trace"}"#).unwrap();
    assert_eq!(reply.get("enabled"), Some(&Json::Bool(false)));
    let replicas = reply.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(replicas[0].get("trace"), Some(&Json::Null));
}

#[test]
fn trace_slow_log_is_scrapeable_with_complete_timelines() {
    // Threshold 0 pins every request into the slow-log.
    let config = EngineConfig { trace_slow_ms: Some(0), ..TestServer::default_config() };
    let server = TestServer::start(1, config);
    let mut client = Client::connect(server.addr).unwrap();
    let reply =
        client.prompt(9, "Describe the water cycle.").max_new(4).send_json().unwrap();
    assert!(reply.get("error").is_none(), "{}", reply.to_string());

    let trace = client.send_raw(r#"{"cmd": "trace"}"#).unwrap();
    assert_eq!(trace.get("enabled"), Some(&Json::Bool(true)));
    let replicas = trace.get("replicas").unwrap().as_arr().unwrap();
    let ring = replicas[0].get("trace").unwrap();
    let slow = ring.get("slow").unwrap().as_arr().unwrap();
    assert_eq!(slow.len(), 1, "{}", ring.to_string());
    let t = &slow[0];
    assert_eq!(t.get("outcome").unwrap().as_str(), Some("completed"));
    assert!(t.get("total_us").unwrap().as_f64().unwrap() > 0.0);
    let events = t.get("events").unwrap().as_arr().unwrap();
    let kinds: Vec<&str> =
        events.iter().map(|e| e.get("event").unwrap().as_str().unwrap()).collect();
    assert_eq!(kinds.first(), Some(&"admitted"));
    assert_eq!(kinds.last(), Some(&"terminal"));
    assert!(kinds.contains(&"seated"), "{kinds:?}");
    assert!(kinds.contains(&"first_token"), "{kinds:?}");
    let mut prev = 0.0;
    for e in events {
        let t_us = e.get("t_us").unwrap().as_f64().unwrap();
        assert!(t_us >= prev, "timeline not monotone: {}", t.to_string());
        prev = t_us;
    }
}

#[test]
fn deadline_exceeded_request_is_pinned_despite_high_threshold() {
    // 60 s threshold: only *misbehaving* requests can reach the
    // slow-log. The batcher's top-up wait (50 ms here) makes the trip
    // deterministic: a lone request is picked up instantly but seated
    // only after `max_wait`, by which point its 1 ms budget has
    // expired — the pre-seat lifecycle checkpoint sheds it.
    let config = EngineConfig {
        trace_slow_ms: Some(60_000),
        batch: BatchPolicy { max_wait: Duration::from_millis(50), ..Default::default() },
        ..TestServer::default_config()
    };
    let server = TestServer::start(1, config);
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client
        .prompt(11, "why is the sky blue?")
        .max_new(8)
        .deadline_ms(1)
        .send_json()
        .unwrap();
    assert_eq!(
        reply.get("code").and_then(|c| c.as_str()),
        Some("deadline_exceeded"),
        "{reply:?}"
    );

    let trace = client.send_raw(r#"{"cmd": "trace"}"#).unwrap();
    let replicas = trace.get("replicas").unwrap().as_arr().unwrap();
    let ring = replicas[0].get("trace").unwrap();
    let slow = ring.get("slow").unwrap().as_arr().unwrap();
    assert_eq!(slow.len(), 1, "{}", ring.to_string());
    let t = &slow[0];
    assert_eq!(t.get("outcome").unwrap().as_str(), Some("deadline_exceeded"));
    let events = t.get("events").unwrap().as_arr().unwrap();
    let kinds: Vec<&str> =
        events.iter().map(|e| e.get("event").unwrap().as_str().unwrap()).collect();
    assert_eq!(kinds.first(), Some(&"admitted"));
    assert_eq!(kinds.last(), Some(&"terminal"));
}

#[test]
fn layer_profile_rows_ride_the_metrics_scrape() {
    let config =
        EngineConfig { profile_layers: true, ..TestServer::default_config() };
    let server = TestServer::start(1, config);
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client.prompt(3, "Count to five.").max_new(4).send_json().unwrap();
    assert!(reply.get("error").is_none(), "{}", reply.to_string());

    let reply = client.send_raw(r#"{"cmd": "metrics"}"#).unwrap();
    let replicas = reply.get("replicas").unwrap().as_arr().unwrap();
    let m = replicas[0].get("metrics").unwrap();
    let layers = m.get("layers").expect("profiling on → layers key").as_arr().unwrap();
    assert!(!layers.is_empty());
    let names: Vec<&str> =
        layers.iter().map(|l| l.get("layer").unwrap().as_str().unwrap()).collect();
    assert!(names.contains(&"lm_head"), "{names:?}");
    for l in layers {
        assert!(l.get("count").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get("total_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(l.get("backend").unwrap().as_str().is_some());
    }
}

#[test]
fn profiling_off_keeps_metrics_scrape_lean() {
    let server = TestServer::start(1, TestServer::default_config());
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client.prompt(4, "Name a color.").max_new(2).send_json().unwrap();
    assert!(reply.get("error").is_none());
    let reply = client.send_raw(r#"{"cmd": "metrics"}"#).unwrap();
    let replicas = reply.get("replicas").unwrap().as_arr().unwrap();
    let m = replicas[0].get("metrics").unwrap();
    assert!(m.get("layers").is_none(), "profiling off must not emit layer rows");
}

#[test]
fn unknown_control_command_gets_error_without_killing_connection() {
    let server = TestServer::start(1, TestServer::default_config());
    let mut client = Client::connect(server.addr).unwrap();
    let reply = client.send_raw(r#"{"cmd": "flamegraph"}"#).unwrap();
    let err = reply.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("metrics, status, trace or drain"), "{err}");
    assert_eq!(reply.get("code").and_then(|c| c.as_str()), Some("bad_request"));
    let reply = client.prompt(5, "still alive?").max_new(2).send_json().unwrap();
    assert!(reply.get("error").is_none());
}
