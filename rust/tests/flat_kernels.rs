//! Property tests: every flat-plan execution path against the checked
//! reference kernels (`segmented_sum` + `block_product_dense` over the
//! boxed `BlockIndex` form).
//!
//! Grid: `k ∈ {1..8}`, shapes with non-divisible tails (`m % k != 0`),
//! batch sizes `{1, 3, 8}`, thread counts `{1, 2, 8}`. The optimized
//! kernels re-associate f32 additions (4-way accumulators, AVX2
//! gathers, pairwise folds), so comparisons are tolerance-based; paths
//! that share the exact same kernel loop (owned RSR++ vs store-shared)
//! are asserted **bit-identical** where the plan layer guarantees it.

use rsr::kernels::batched::{BatchedRsrPlan, BatchedTernaryRsrPlan};
use rsr::kernels::flat::{segmented_sum_flat, segmented_sum_flat_scalar, FlatPlan};
use rsr::kernels::index::{RsrIndex, TernaryRsrIndex};
use rsr::kernels::parallel::{ParallelRsrPlan, ParallelTernaryRsrPlan};
use rsr::kernels::rsr::{block_product_dense, segmented_sum, RsrPlan, TernaryRsrPlan};
use rsr::kernels::rsrpp::{RsrPlusPlusPlan, TernaryRsrPlusPlusPlan};
use rsr::kernels::{BinaryMatrix, TernaryMatrix};
use rsr::runtime::{SharedRsrPlan, SharedTernaryPlan};
use rsr::util::rng::Rng;

/// The checked reference: `v·B` via the fully bounds-checked, strictly
/// serial kernels on the boxed index.
fn reference_mul(idx: &RsrIndex, v: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; idx.cols];
    for blk in &idx.blocks {
        let w = blk.width as usize;
        let mut u = vec![0.0f32; 1 << w];
        segmented_sum(blk, v, &mut u);
        let col = blk.col_start as usize;
        block_product_dense(&u, w, &mut out[col..col + w]);
    }
    out
}

fn reference_mul_ternary(idx: &TernaryRsrIndex, v: &[f32]) -> Vec<f32> {
    let plus = reference_mul(&idx.plus, v);
    let minus = reference_mul(&idx.minus, v);
    plus.iter().zip(minus.iter()).map(|(p, m)| p - m).collect()
}

fn assert_close(got: &[f32], expect: &[f32], what: &str) {
    assert_eq!(got.len(), expect.len(), "{what}: length");
    for (i, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
        let tol = 1e-3 * (1.0 + e.abs());
        assert!((g - e).abs() <= tol, "{what}[{i}]: {g} vs {e}");
    }
}

/// Shapes whose column counts are prime, so `m % k != 0` (a ragged
/// tail block exists) for every `k ∈ {2..8}`.
fn shape_grid() -> Vec<(usize, usize)> {
    vec![(97, 61), (64, 43), (130, 17)]
}

#[test]
fn binary_plans_match_reference_across_k_grid() {
    let mut rng = Rng::new(0xF1A7);
    for k in 1..=8usize {
        for &(n, m) in &shape_grid() {
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let idx = RsrIndex::preprocess(&b, k);
            let v = rng.f32_vec(n, -2.0, 2.0);
            let expect = reference_mul(&idx, &v);
            if k > 1 {
                assert_ne!(m % k, 0, "grid must exercise the ragged tail");
            }

            let mut out = vec![0.0f32; m];
            let what = format!("k={k} n={n} m={m}");

            let mut rsr = RsrPlan::new(idx.clone()).unwrap();
            rsr.execute(&v, &mut out).unwrap();
            assert_close(&out, &expect, &format!("rsr {what}"));

            let mut pp = RsrPlusPlusPlan::new(idx.clone()).unwrap();
            pp.execute(&v, &mut out).unwrap();
            assert_close(&out, &expect, &format!("rsr++ {what}"));
            let pp_out = out.clone();

            // The store-shared plan runs the identical flat loop →
            // bit-identical to the owned RSR++ plan.
            let shared = SharedRsrPlan::new(idx.clone()).unwrap();
            let mut scratch = shared.scratch();
            shared.execute(&mut scratch, &v, &mut out).unwrap();
            assert_eq!(out, pp_out, "shared vs owned rsr++ {what}");
        }
    }
}

#[test]
fn ternary_plans_match_reference_across_k_grid() {
    let mut rng = Rng::new(0xF1A8);
    for k in 1..=8usize {
        let (n, m) = (73, 41);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let expect = reference_mul_ternary(&idx, &v);
        let mut out = vec![0.0f32; m];
        let what = format!("ternary k={k}");

        let mut rsr = TernaryRsrPlan::new(idx.clone()).unwrap();
        rsr.execute(&v, &mut out).unwrap();
        assert_close(&out, &expect, &format!("rsr {what}"));

        let mut pp = TernaryRsrPlusPlusPlan::new(idx.clone()).unwrap();
        pp.execute(&v, &mut out).unwrap();
        assert_close(&out, &expect, &format!("rsr++ {what}"));
        let pp_out = out.clone();

        let shared = SharedTernaryPlan::new(idx.clone()).unwrap();
        let mut scratch = shared.scratch();
        shared.execute(&mut scratch, &v, &mut out).unwrap();
        assert_eq!(out, pp_out, "shared vs owned {what}");
    }
}

#[test]
fn batched_plans_match_reference_across_batch_sizes() {
    let mut rng = Rng::new(0xF1A9);
    for k in [1usize, 3, 5, 8] {
        for &batch in &[1usize, 3, 8] {
            let (n, m) = (97, 61);
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let idx = RsrIndex::preprocess(&b, k);
            let vs = rng.f32_vec(batch * n, -1.0, 1.0);
            let mut plan = BatchedRsrPlan::new(idx.clone(), batch).unwrap();
            let mut out = vec![0.0f32; batch * m];
            plan.execute(&vs, batch, &mut out).unwrap();
            for bi in 0..batch {
                let expect = reference_mul(&idx, &vs[bi * n..(bi + 1) * n]);
                assert_close(
                    &out[bi * m..(bi + 1) * m],
                    &expect,
                    &format!("batched k={k} batch={batch} row={bi}"),
                );
            }
        }
    }
}

#[test]
fn batched_ternary_matches_reference_across_batch_sizes() {
    let mut rng = Rng::new(0xF1AA);
    for &batch in &[1usize, 3, 8] {
        let (n, m, k) = (73, 41, 4);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let vs = rng.f32_vec(batch * n, -1.0, 1.0);
        let mut plan = BatchedTernaryRsrPlan::new(idx.clone(), batch).unwrap();
        let mut out = vec![0.0f32; batch * m];
        plan.execute(&vs, batch, &mut out).unwrap();
        for bi in 0..batch {
            let expect = reference_mul_ternary(&idx, &vs[bi * n..(bi + 1) * n]);
            assert_close(
                &out[bi * m..(bi + 1) * m],
                &expect,
                &format!("batched ternary batch={batch} row={bi}"),
            );
        }
    }
}

#[test]
fn parallel_plans_match_reference_across_thread_counts() {
    let mut rng = Rng::new(0xF1AB);
    for &threads in &[1usize, 2, 8] {
        for k in [1usize, 4, 8] {
            let (n, m) = (130, 67);
            let b = BinaryMatrix::random(n, m, 0.5, &mut rng);
            let idx = RsrIndex::preprocess(&b, k);
            let v = rng.f32_vec(n, -1.0, 1.0);
            let expect = reference_mul(&idx, &v);
            let mut plan = ParallelRsrPlan::new(idx, threads).unwrap();
            let mut out = vec![0.0f32; m];
            // Repeat to exercise pool generation reuse.
            for round in 0..3 {
                plan.execute(&v, &mut out).unwrap();
                assert_close(
                    &out,
                    &expect,
                    &format!("parallel threads={threads} k={k} round={round}"),
                );
            }
        }
    }
}

#[test]
fn parallel_ternary_matches_reference_across_thread_counts() {
    let mut rng = Rng::new(0xF1AC);
    for &threads in &[1usize, 2, 8] {
        let (n, m, k) = (96, 51, 4);
        let a = TernaryMatrix::random(n, m, 1.0 / 3.0, &mut rng);
        let idx = TernaryRsrIndex::preprocess(&a, k);
        let v = rng.f32_vec(n, -1.0, 1.0);
        let expect = reference_mul_ternary(&idx, &v);
        let mut plan = ParallelTernaryRsrPlan::new(idx, threads).unwrap();
        let mut out = vec![0.0f32; m];
        for round in 0..3 {
            plan.execute(&v, &mut out).unwrap();
            assert_close(
                &out,
                &expect,
                &format!("parallel ternary threads={threads} round={round}"),
            );
        }
    }
}

/// Both dispatch arms of the segmented sum (runtime SIMD pick vs the
/// pinned scalar kernel) against the checked reference, per block, on
/// segment lengths crossing all unroll widths.
#[test]
fn simd_dispatch_and_scalar_paths_agree_with_reference() {
    let mut rng = Rng::new(0xF1AD);
    for k in 1..=8usize {
        let (n, m) = (257, 33); // ragged everywhere, segments of many lengths
        let b = BinaryMatrix::random(n, m, 0.3, &mut rng);
        let idx = RsrIndex::preprocess(&b, k);
        let flat = FlatPlan::from_index(&idx).unwrap();
        let v = rng.f32_vec(n, -1.0, 1.0);
        for (i, blk) in idx.blocks.iter().enumerate() {
            let two_w = 1usize << blk.width;
            let mut expect = vec![0.0f32; two_w];
            segmented_sum(blk, &v, &mut expect);
            let mut scalar = vec![0.0f32; two_w];
            // SAFETY: block slices of a validated FlatPlan; v.len() == rows.
            unsafe {
                segmented_sum_flat_scalar(flat.block_sigma(i), flat.block_seg(i), &v, &mut scalar);
            }
            let mut dispatched = vec![0.0f32; two_w];
            // SAFETY: as above.
            unsafe {
                segmented_sum_flat(flat.block_sigma(i), flat.block_seg(i), &v, &mut dispatched);
            }
            for j in 0..two_w {
                let tol = 1e-4 * (1.0 + expect[j].abs());
                assert!(
                    (scalar[j] - expect[j]).abs() <= tol,
                    "scalar k={k} block={i} seg={j}"
                );
                assert!(
                    (dispatched[j] - expect[j]).abs() <= tol,
                    "dispatch k={k} block={i} seg={j}"
                );
            }
        }
    }
}
