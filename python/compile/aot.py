"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the rust runtime.

Interchange format is HLO text, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly (see
``/opt/xla-example/README.md``).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per computation plus ``manifest.json``
describing every artifact's inputs/outputs, which the rust
``runtime::Engine`` reads.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Sizes kept modest so `make artifacts` stays in tens of seconds; the
# rust-native kernels (not PJRT) carry the paper's full 2^16 range.
DENSE_SIZES = [1024, 2048, 4096]
BATCHED = [(8, 2048)]
RSR_SIZES = [(1024, 8)]  # (n, k)
FFN_SHAPES = [(1024, 4096)]  # (d, ff)
RSR_FFN_SHAPES = [(256, 512, 4)]  # (d, ff, k) — L2 block calling the L1 kernel 3×


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def build_artifacts():
    """Yield ``(name, lowered, input_specs, output_specs, meta)``."""
    f32 = jnp.float32
    i32 = jnp.int32

    for n in DENSE_SIZES:
        v = jax.ShapeDtypeStruct((n,), f32)
        w = jax.ShapeDtypeStruct((n, n), f32)
        yield (
            f"dense_matvec_n{n}",
            jax.jit(model.dense_matvec).lower(v, w),
            [_spec((n,)), _spec((n, n))],
            [_spec((n,))],
            {"kind": "dense_matvec", "n": n},
        )

    for b, n in BATCHED:
        vs = jax.ShapeDtypeStruct((b, n), f32)
        w = jax.ShapeDtypeStruct((n, n), f32)
        yield (
            f"dense_matvec_b{b}_n{n}",
            jax.jit(model.dense_matvec_batched).lower(vs, w),
            [_spec((b, n)), _spec((n, n))],
            [_spec((b, n))],
            {"kind": "dense_matvec_batched", "batch": b, "n": n},
        )

    for n, k in RSR_SIZES:
        nb = n // k
        v = jax.ShapeDtypeStruct((n,), f32)
        keys = jax.ShapeDtypeStruct((nb, n), i32)
        binm = jax.ShapeDtypeStruct((2**k, k), f32)
        fn = functools.partial(model.rsr_matvec, k=k)
        yield (
            f"rsr_matvec_n{n}_k{k}",
            jax.jit(fn).lower(v, keys, binm),
            [_spec((n,)), _spec((nb, n), "i32"), _spec((2**k, k))],
            [_spec((n,))],
            {"kind": "rsr_matvec", "n": n, "k": k},
        )

    for d, ff in FFN_SHAPES:
        x = jax.ShapeDtypeStruct((d,), f32)
        wg = jax.ShapeDtypeStruct((d, ff), f32)
        wu = jax.ShapeDtypeStruct((d, ff), f32)
        wd = jax.ShapeDtypeStruct((ff, d), f32)
        yield (
            f"ffn_dense_d{d}_ff{ff}",
            jax.jit(model.swiglu_ffn_dense).lower(x, wg, wu, wd),
            [_spec((d,)), _spec((d, ff)), _spec((d, ff)), _spec((ff, d))],
            [_spec((d,))],
            {"kind": "ffn_dense", "d": d, "ff": ff},
        )

    # The full L2-calls-L1 composition: a SwiGLU block whose three
    # projections each run the Pallas RSR kernel, lowered as one HLO.
    for d, ff, k in RSR_FFN_SHAPES:
        x = jax.ShapeDtypeStruct((d,), f32)
        keys_g = jax.ShapeDtypeStruct((ff // k, d), i32)
        keys_u = jax.ShapeDtypeStruct((ff // k, d), i32)
        keys_d = jax.ShapeDtypeStruct((d // k, ff), i32)
        binm = jax.ShapeDtypeStruct((2**k, k), f32)
        fn = functools.partial(model.swiglu_ffn_rsr, k=k)
        yield (
            f"ffn_rsr_d{d}_ff{ff}_k{k}",
            jax.jit(fn).lower(x, keys_g, keys_u, keys_d, binm),
            [
                _spec((d,)),
                _spec((ff // k, d), "i32"),
                _spec((ff // k, d), "i32"),
                _spec((d // k, ff), "i32"),
                _spec((2**k, k)),
            ],
            [_spec((d,))],
            {"kind": "ffn_rsr", "d": d, "ff": ff, "k": k},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for name, lowered, inputs, outputs, meta in build_artifacts():
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "path": path,
                "inputs": inputs,
                "outputs": outputs,
                "meta": meta,
            }
        )
        print(f"  wrote {path} ({len(text)} chars)")

    # A couple of tiny fixtures used by rust runtime tests: known
    # matrices so the rust side can assert exact numerics.
    _ = ref  # (ref is exercised by pytest; imported here for parity)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
