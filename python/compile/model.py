"""Layer-2: the JAX model — BitLinear-style compute graphs that call the
Layer-1 Pallas kernel, plus the dense baselines, all AOT-lowered by
``aot.py`` into the HLO artifacts the rust runtime executes.

Python never runs at serving time: these functions exist to be lowered
once (``make artifacts``) and to be tested against ``kernels.ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import rsr_pallas


def dense_matvec(v, w):
    """The optimized-library baseline: ``v @ W`` (PJRT compiles this to
    its Eigen dot — the stand-in for NumPy/cuBLAS in Fig 11)."""
    return (v @ w,)


def dense_matvec_batched(vs, w):
    """Batched baseline ``V @ W`` for the serving/GPU comparisons."""
    return (vs @ w,)


def rsr_matvec(v, keys, binm, *, k: int):
    """The RSR product as an XLA computation: Layer-2 entry point that
    calls the Layer-1 Pallas kernel."""
    return (rsr_pallas.rsr_matvec_binary(v, keys, binm, k=k),)


def rsr_matvec_ternary(v, keys_plus, keys_minus, binm, *, k: int):
    """Ternary RSR product (Prop 2.1) calling the Pallas kernel twice."""
    return (
        rsr_pallas.rsr_matvec_ternary(v, keys_plus, keys_minus, binm, k=k),
    )


def swiglu_ffn_dense(x, w_gate, w_up, w_down):
    """Dense SwiGLU feed-forward block (the transformer's hot layer):
    ``down( silu(gate(x)) * up(x) )`` — the PJRT model-level baseline."""
    g = x @ w_gate
    u = x @ w_up
    h = jax.nn.silu(g) * u
    return (h @ w_down,)


def swiglu_ffn_rsr(x, keys_g, keys_u, keys_d, binm, *, k: int):
    """SwiGLU block with every projection running the RSR Pallas kernel
    (binary weights; the ternary variant doubles the key inputs).

    Layer widths are implied by the key shapes: ``keys_g/keys_u`` index
    ``d → ff`` matrices, ``keys_d`` the ``ff → d`` matrix.
    """
    g = rsr_pallas.rsr_matvec_binary(x, keys_g, binm, k=k)
    u = rsr_pallas.rsr_matvec_binary(x, keys_u, binm, k=k)
    h = jax.nn.silu(g) * u
    return (rsr_pallas.rsr_matvec_binary(h, keys_d, binm, k=k),)


def rmsnorm(x, weight, eps: float = 1e-6):
    """RMSNorm (matches ``rust/src/model/rmsnorm.rs``)."""
    ms = jnp.mean(x * x)
    return x * jax.lax.rsqrt(ms + eps) * weight


def decoder_ffn_halfblock_dense(h, norm_w, w_gate, w_up, w_down):
    """Pre-norm residual FFN half-block: ``h + ffn(rmsnorm(h))`` — the
    shape the paper's §5.3 per-layer timing actually exercises."""
    x = rmsnorm(h, norm_w)
    (y,) = swiglu_ffn_dense(x, w_gate, w_up, w_down)
    return (h + y,)
