"""Layer-1 Pallas kernel: RSR in its tensorized (MXU-friendly) form.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's GPU
variant (Appendix C.1.II / E.3) replaces permutation + segmentation with
a one-hot segmentation matrix so the segmented sum becomes a matmul. On
TPU that is exactly the right shape for the MXU systolic array, so the
kernel computes, per column block ``b``:

    onehot = (keys_b[:, None] == iota(2^k))        # (n, 2^k) 0/1
    u      = v @ onehot                            # segmented sums
    out_b  = u @ Bin_[k]                           # block product

The grid iterates over column blocks; ``BlockSpec`` streams the per-
block key rows through VMEM while ``v`` and the tiny ``Bin_[k]`` stay
resident. ``interpret=True`` everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU estimates live in EXPERIMENTS.md §Perf.

VMEM footprint per grid step (f32): ``n·2^k`` (one-hot) + ``n`` (v) +
``n`` (keys) + ``2^k·k`` (Bin) + ``k`` (out). With the default tiling
``ROW_TILE = 2048``, a ``k = 8`` kernel uses ~2.1 MB — comfortably
inside the ~16 MB VMEM of a TPU core, leaving room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# Rows processed per inner tile. Chosen so the one-hot tile
# (ROW_TILE × 2^k f32) stays ~2 MB at k=8; see module docstring.
ROW_TILE = 2048


def _rsr_block_kernel(v_ref, keys_ref, bin_ref, o_ref):
    """One grid step: one column block, full row range.

    The one-hot segmented-sum matmul runs in row tiles so the VMEM
    working set is bounded regardless of n.
    """
    v = v_ref[...]  # (n,)
    keys = keys_ref[0]  # (n,)
    binm = bin_ref[...]  # (2^k, k)
    n = v.shape[0]
    two_k = binm.shape[0]

    u = jnp.zeros((two_k,), dtype=v.dtype)
    # Static tiling (n and ROW_TILE are compile-time constants).
    for start in range(0, n, ROW_TILE):
        stop = min(start + ROW_TILE, n)
        kt = keys[start:stop]
        vt = v[start:stop]
        iota = jax.lax.broadcasted_iota(jnp.int32, (stop - start, two_k), 1)
        onehot = (kt[:, None] == iota).astype(v.dtype)  # (tile, 2^k)
        u = u + vt @ onehot
    o_ref[0, :] = u @ binm


@functools.partial(jax.jit, static_argnames=("k",))
def rsr_matvec_binary(v, keys, binm, *, k: int):
    """``v @ B`` for binary ``B`` given precomputed block keys.

    Args:
      v:    f32[n] activation vector.
      keys: i32[n_blocks, n] k-bit row keys per block
            (``ref.block_keys``; the build-time product of Algorithm 1).
      binm: f32[2^k, k] the ``Bin_[k]`` matrix (``ref.bin_matrix``).
      k:    block width (static).

    Returns:
      f32[n_blocks * k] — the product vector.
    """
    nb, n = keys.shape
    out = pl.pallas_call(
        _rsr_block_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n,), lambda b: (0,)),
            pl.BlockSpec((1, n), lambda b: (b, 0)),
            pl.BlockSpec((2**k, k), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, k), v.dtype),
        interpret=True,
    )(v, keys, binm)
    return out.reshape(nb * k)


@functools.partial(jax.jit, static_argnames=("k",))
def rsr_matvec_ternary(v, keys_plus, keys_minus, binm, *, k: int):
    """Ternary ``v @ A`` via Prop 2.1: RSR on both binary halves."""
    plus = rsr_matvec_binary(v, keys_plus, binm, k=k)
    minus = rsr_matvec_binary(v, keys_minus, binm, k=k)
    return plus - minus


def prepare_binary(B: np.ndarray, k: int):
    """Build-time preprocessing for :func:`rsr_matvec_binary`.

    Pads the column count up to a multiple of ``k`` (extra zero columns
    produce zero outputs that callers slice off).
    """
    n, m = B.shape
    pad = (-m) % k
    if pad:
        B = np.concatenate([B, np.zeros((n, pad), dtype=B.dtype)], axis=1)
    keys = ref.block_keys(B, k)
    binm = ref.bin_matrix(k)
    return keys, binm, m


def prepare_ternary(A: np.ndarray, k: int):
    """Build-time preprocessing for :func:`rsr_matvec_ternary`."""
    B1, B2 = ref.decompose_ternary(A)
    keys_p, binm, m = prepare_binary(B1, k)
    keys_m, _, _ = prepare_binary(B2, k)
    return keys_p, keys_m, binm, m


def rsr_apply_binary(v: np.ndarray, B: np.ndarray, k: int) -> np.ndarray:
    """Convenience one-shot: preprocess + kernel + unpad."""
    keys, binm, m = prepare_binary(B, k)
    out = rsr_matvec_binary(jnp.asarray(v), jnp.asarray(keys), jnp.asarray(binm), k=k)
    return np.asarray(out)[:m]


def rsr_apply_ternary(v: np.ndarray, A: np.ndarray, k: int) -> np.ndarray:
    """Convenience one-shot for ternary matrices."""
    kp, km, binm, m = prepare_ternary(A, k)
    out = rsr_matvec_ternary(
        jnp.asarray(v), jnp.asarray(kp), jnp.asarray(km), jnp.asarray(binm), k=k
    )
    return np.asarray(out)[:m]


def vmem_bytes(n: int, k: int, row_tile: int = ROW_TILE) -> int:
    """Estimated per-step VMEM footprint in bytes (f32 everywhere).

    Used by the §Perf analysis: one-hot tile + v + keys + Bin + out.
    """
    tile = min(n, row_tile)
    onehot = tile * (2**k) * 4
    v_bytes = n * 4
    keys_bytes = n * 4
    bin_bytes = (2**k) * k * 4
    return onehot + v_bytes + keys_bytes + bin_bytes + k * 4


def mxu_utilization_estimate(n: int, k: int) -> float:
    """Fraction of one-hot matmul MACs that contribute to the result.

    The MXU executes the full ``n × 2^k`` one-hot product (n·2^k MACs
    per block); the useful work of the segmented sum is n adds per
    block, so utilization of the *useful* adds is ``n / (n·2^k) = 2^-k``
    — the tensorized form trades redundant MACs for systolic-array
    throughput exactly as the paper's GPU version does with cuBLAS.
    Reported (not optimized away) in EXPERIMENTS.md §Perf.
    """
    return 1.0 / (2**k)
