"""Pure-numpy reference oracle for the RSR algorithms.

This is the correctness anchor for the Layer-1 Pallas kernel: every
kernel output is compared against these functions by pytest/hypothesis.
It mirrors the paper exactly:

* :func:`bin_matrix` — the ``Bin_[k]`` enumeration matrix (paper §3.2),
* :func:`block_keys` — the k-bit row value per column block (Def 3.2),
* :func:`preprocess` — Algorithm 1 (blocking, binary row order, full
  segmentation),
* :func:`rsr_matvec_ref` — Algorithm 2 over the preprocessed index,
* :func:`decompose_ternary` — Proposition 2.1.
"""

from __future__ import annotations

import numpy as np


def bin_matrix(k: int) -> np.ndarray:
    """The ``2^k x k`` binary-row-ordered enumeration matrix ``Bin_[k]``.

    Column 0 holds the MSB, matching the paper's row-value convention
    ``B_i[r,:]_2 = concat(B[r,1..k])``.
    """
    if not 1 <= k <= 16:
        raise ValueError(f"k={k} out of range 1..16")
    values = np.arange(2**k, dtype=np.int64)
    shifts = (k - 1 - np.arange(k, dtype=np.int64))[None, :]
    return ((values[:, None] >> shifts) & 1).astype(np.float32)


def block_keys(B: np.ndarray, k: int) -> np.ndarray:
    """Per-block k-bit row keys: shape ``(n_blocks, n_rows)`` int32.

    ``B`` must be a 0/1 matrix whose column count is divisible by ``k``
    (callers pad the ragged tail; the rust side handles it natively).
    """
    n, m = B.shape
    if m % k != 0:
        raise ValueError(f"cols {m} not divisible by k={k} (pad first)")
    nb = m // k
    blocks = B.reshape(n, nb, k).astype(np.int64)
    shifts = (k - 1 - np.arange(k, dtype=np.int64))[None, None, :]
    keys = (blocks << shifts).sum(axis=2)
    return keys.T.astype(np.int32)  # (nb, n)


def preprocess(B: np.ndarray, k: int):
    """Algorithm 1: returns ``[(sigma, seg), ...]`` per column block.

    ``sigma[pos] = original_row`` (stable, ascending key order) and
    ``seg`` is the full segmentation with sentinel: ``2^k + 1`` entries.
    """
    keys = block_keys(B, k)
    out = []
    for bkeys in keys:
        sigma = np.argsort(bkeys, kind="stable").astype(np.uint32)
        counts = np.bincount(bkeys, minlength=2**k).astype(np.uint32)
        seg = np.zeros(2**k + 1, dtype=np.uint32)
        seg[1:] = np.cumsum(counts)
        out.append((sigma, seg))
    return out


def segmented_sum(v: np.ndarray, sigma: np.ndarray, seg: np.ndarray) -> np.ndarray:
    """Eq 5: segmented sums of ``v`` under ``(sigma, seg)``."""
    perm = v[sigma]
    sums = np.add.reduceat(
        np.concatenate([perm, [0.0]]), seg[:-1].astype(np.int64)
    )[: len(seg) - 1]
    # reduceat quirk: empty segments (seg[j] == seg[j+1]) copy the
    # element instead of summing zero — fix them up.
    empty = seg[:-1] == seg[1:]
    sums = np.where(empty, 0.0, sums)
    return sums.astype(v.dtype)


def rsr_matvec_ref(v: np.ndarray, B: np.ndarray, k: int) -> np.ndarray:
    """Algorithm 2 (reference): ``v @ B`` via segmented sums + Bin_[k]."""
    n, m = B.shape
    if v.shape != (n,):
        raise ValueError("shape mismatch")
    binm = bin_matrix(k)
    out = np.zeros(m, dtype=np.float32)
    for bi, (sigma, seg) in enumerate(preprocess(B, k)):
        u = segmented_sum(v.astype(np.float32), sigma, seg)
        out[bi * k : (bi + 1) * k] = u @ binm
    return out


def decompose_ternary(A: np.ndarray):
    """Proposition 2.1: ``A = B1 - B2`` with binary ``B1, B2``."""
    B1 = (A == 1).astype(np.float32)
    B2 = (A == -1).astype(np.float32)
    return B1, B2


def rsr_matvec_ternary_ref(v: np.ndarray, A: np.ndarray, k: int) -> np.ndarray:
    """Ternary Algorithm 2 via Prop 2.1."""
    B1, B2 = decompose_ternary(A)
    return rsr_matvec_ref(v, B1, k) - rsr_matvec_ref(v, B2, k)


def dense_matvec_ref(v: np.ndarray, W: np.ndarray) -> np.ndarray:
    """The standard baseline: ``v @ W``."""
    return v @ W
