"""AOT sanity: every artifact lowers to parseable HLO text with the
declared entry signature, and the manifest is consistent."""

import json

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts():
    # Lowering (no compilation) is fast enough to do once per session.
    return list(aot.build_artifacts())


def test_expected_artifact_set(artifacts):
    names = {a[0] for a in artifacts}
    for n in aot.DENSE_SIZES:
        assert f"dense_matvec_n{n}" in names
    for n, k in aot.RSR_SIZES:
        assert f"rsr_matvec_n{n}_k{k}" in names
    for d, ff in aot.FFN_SHAPES:
        assert f"ffn_dense_d{d}_ff{ff}" in names
    for d, ff, k in aot.RSR_FFN_SHAPES:
        assert f"ffn_rsr_d{d}_ff{ff}_k{k}" in names


def test_hlo_text_is_emitted_and_parseable_shape(artifacts):
    # Use the smallest artifact to keep the test quick.
    name, lowered, inputs, outputs, meta = min(
        artifacts, key=lambda a: a[3][0]["shape"][0] if a[3][0]["shape"] else 0
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Parameter count matches the declared inputs.
    assert text.count("parameter(") >= len(inputs)


def test_manifest_written(tmp_path, monkeypatch):
    # Run main() against a temp dir with trimmed sizes for speed.
    monkeypatch.setattr(aot, "DENSE_SIZES", [64])
    monkeypatch.setattr(aot, "BATCHED", [(2, 64)])
    monkeypatch.setattr(aot, "RSR_SIZES", [(64, 4)])
    monkeypatch.setattr(aot, "FFN_SHAPES", [(32, 64)])
    monkeypatch.setattr(aot, "RSR_FFN_SHAPES", [(32, 64, 4)])
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) == 5
    for art in manifest["artifacts"]:
        assert (tmp_path / art["path"]).exists()
        text = (tmp_path / art["path"]).read_text()
        assert "HloModule" in text
        assert all("shape" in s and "dtype" in s for s in art["inputs"])
