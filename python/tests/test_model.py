"""Layer-2 model graphs: RSR path vs dense path parity, shapes."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref, rsr_pallas


def test_dense_matvec():
    rng = np.random.default_rng(0)
    v = rng.normal(size=32).astype(np.float32)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    (out,) = model.dense_matvec(v, w)
    np.testing.assert_allclose(np.asarray(out), v @ w, rtol=1e-5)


def test_dense_matvec_batched():
    rng = np.random.default_rng(1)
    vs = rng.normal(size=(4, 32)).astype(np.float32)
    w = rng.normal(size=(32, 32)).astype(np.float32)
    (out,) = model.dense_matvec_batched(vs, w)
    np.testing.assert_allclose(np.asarray(out), vs @ w, rtol=1e-5)


def test_rsr_matvec_graph_matches_dense():
    rng = np.random.default_rng(2)
    n, k = 48, 4
    B = (rng.random((n, n)) < 0.5).astype(np.float32)
    v = rng.normal(size=n).astype(np.float32)
    keys, binm, m = rsr_pallas.prepare_binary(B, k)
    (out,) = model.rsr_matvec(v, keys, binm, k=k)
    np.testing.assert_allclose(np.asarray(out)[:m], v @ B, rtol=1e-4, atol=1e-4)


def test_rsr_matvec_ternary_graph():
    rng = np.random.default_rng(3)
    n, k = 40, 4
    A = rng.integers(-1, 2, (n, n)).astype(np.float32)
    v = rng.normal(size=n).astype(np.float32)
    kp, km, binm, m = rsr_pallas.prepare_ternary(A, k)
    (out,) = model.rsr_matvec_ternary(v, kp, km, binm, k=k)
    np.testing.assert_allclose(np.asarray(out)[:m], v @ A, rtol=1e-4, atol=1e-4)


def test_ffn_rsr_matches_ffn_dense():
    """The Layer-2 composition check: a SwiGLU block whose three
    projections run the Pallas kernel must match the dense block."""
    rng = np.random.default_rng(4)
    d = ff = 32  # square so one Bin/k serves all three (keys differ)
    k = 4
    Wg = (rng.random((d, ff)) < 0.5).astype(np.float32)
    Wu = (rng.random((d, ff)) < 0.5).astype(np.float32)
    Wd = (rng.random((ff, d)) < 0.5).astype(np.float32)
    x = rng.normal(size=d).astype(np.float32)

    keys_g, binm, _ = rsr_pallas.prepare_binary(Wg, k)
    keys_u, _, _ = rsr_pallas.prepare_binary(Wu, k)
    keys_d, _, _ = rsr_pallas.prepare_binary(Wd, k)

    (got,) = model.swiglu_ffn_rsr(x, keys_g, keys_u, keys_d, binm, k=k)
    (expect,) = model.swiglu_ffn_dense(x, Wg, Wu, Wd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), rtol=1e-3, atol=1e-3)


def test_rmsnorm_matches_manual():
    x = np.array([3.0, 4.0], dtype=np.float32)
    w = np.array([1.0, 2.0], dtype=np.float32)
    got = np.asarray(model.rmsnorm(x, w))
    rms = np.sqrt((x**2).mean() + 1e-6)
    np.testing.assert_allclose(got, x / rms * w, rtol=1e-5)


def test_decoder_halfblock_residual():
    rng = np.random.default_rng(5)
    d, ff = 16, 32
    h = rng.normal(size=d).astype(np.float32)
    norm_w = np.ones(d, dtype=np.float32)
    Wg = rng.normal(size=(d, ff)).astype(np.float32)
    Wu = rng.normal(size=(d, ff)).astype(np.float32)
    Wd = rng.normal(size=(ff, d)).astype(np.float32)
    (out,) = model.decoder_ffn_halfblock_dense(h, norm_w, Wg, Wu, Wd)
    x = np.asarray(model.rmsnorm(h, norm_w))
    (y,) = model.swiglu_ffn_dense(x, Wg, Wu, Wd)
    np.testing.assert_allclose(np.asarray(out), h + np.asarray(y), rtol=1e-5)
