"""Invariants of the pure-numpy reference implementation itself
(Algorithm 1 structure, paper worked examples, Prop 2.1)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


PAPER_MATRIX = np.array(
    [
        [0, 1, 1, 1, 0, 1],
        [0, 0, 0, 1, 1, 1],
        [0, 1, 1, 1, 1, 0],
        [1, 1, 0, 0, 1, 0],
        [0, 0, 1, 1, 0, 1],
        [0, 0, 0, 0, 1, 0],
    ],
    dtype=np.float32,
)


class TestPaperExamples:
    def test_example_3_3_permutation(self):
        # Paper σ = ⟨2,5,6,1,3,4⟩ (1-based) for block 1 of the running
        # example → 0-based [1,4,5,0,2,3].
        (sigma, seg), *_ = ref.preprocess(PAPER_MATRIX, 2)
        np.testing.assert_array_equal(sigma, [1, 4, 5, 0, 2, 3])

    def test_example_3_3_segmentation(self):
        # Paper Full Segmentation [1,4,6,6] (1-based) → ours 0-based
        # with sentinel: [0,3,5,5,6].
        (sigma, seg), *_ = ref.preprocess(PAPER_MATRIX, 2)
        np.testing.assert_array_equal(seg, [0, 3, 5, 5, 6])

    def test_def_4_1_segmented_sum(self):
        # v_π = [3,2,4,5,9,1] → SS = [9,14,0,1]; build v so that
        # v[σ(pos)] = v_π[pos].
        (sigma, seg), *_ = ref.preprocess(PAPER_MATRIX, 2)
        v_pi = np.array([3, 2, 4, 5, 9, 1], dtype=np.float32)
        v = np.zeros(6, dtype=np.float32)
        v[sigma] = v_pi
        np.testing.assert_array_equal(
            ref.segmented_sum(v, sigma, seg), [9, 14, 0, 1]
        )

    def test_bin_matrix_paper_values(self):
        np.testing.assert_array_equal(
            ref.bin_matrix(2), [[0, 0], [0, 1], [1, 0], [1, 1]]
        )
        # Bin_[3] row 5 = 101.
        np.testing.assert_array_equal(ref.bin_matrix(3)[5], [1, 0, 1])


class TestStructuralInvariants:
    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(4, 80),
        nb=st.integers(1, 5),
        k=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_preprocess_invariants(self, n, nb, k, seed):
        rng = np.random.default_rng(seed)
        B = (rng.random((n, nb * k)) < 0.5).astype(np.float32)
        for sigma, seg in ref.preprocess(B, k):
            # σ is a bijection.
            assert sorted(sigma) == list(range(n))
            # L is monotone with the right endpoints.
            assert seg[0] == 0 and seg[-1] == n
            assert (np.diff(seg.astype(np.int64)) >= 0).all()
            assert len(seg) == 2**k + 1

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(4, 60),
        nb=st.integers(1, 5),
        k=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_rsr_ref_matches_dense(self, n, nb, k, seed):
        rng = np.random.default_rng(seed)
        B = (rng.random((n, nb * k)) < 0.5).astype(np.float32)
        v = rng.normal(size=n).astype(np.float32)
        np.testing.assert_allclose(
            ref.rsr_matvec_ref(v, B, k), v @ B, rtol=1e-3, atol=1e-3
        )

    def test_prop_2_1(self):
        rng = np.random.default_rng(0)
        A = rng.integers(-1, 2, (20, 20)).astype(np.float32)
        B1, B2 = ref.decompose_ternary(A)
        np.testing.assert_array_equal(B1 - B2, A)
        assert ((B1 == 0) | (B1 == 1)).all()
        assert ((B2 == 0) | (B2 == 1)).all()
        assert not ((B1 == 1) & (B2 == 1)).any()

    def test_ternary_ref_matches_dense(self):
        rng = np.random.default_rng(1)
        A = rng.integers(-1, 2, (48, 24)).astype(np.float32)
        v = rng.normal(size=48).astype(np.float32)
        np.testing.assert_allclose(
            ref.rsr_matvec_ternary_ref(v, A, 4), v @ A, rtol=1e-3, atol=1e-3
        )


class TestErrors:
    def test_bad_k_rejected(self):
        with pytest.raises(ValueError):
            ref.bin_matrix(0)
        with pytest.raises(ValueError):
            ref.bin_matrix(17)

    def test_non_divisible_cols_rejected(self):
        with pytest.raises(ValueError):
            ref.block_keys(np.zeros((4, 7), dtype=np.float32), 2)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError):
            ref.rsr_matvec_ref(
                np.zeros(3, np.float32), np.zeros((4, 4), np.float32), 2
            )
