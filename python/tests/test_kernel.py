"""Pallas kernel vs the pure-numpy oracle — the core L1 correctness
signal, with hypothesis sweeping shapes, k and densities."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rsr_pallas


def random_binary(n, m, p, seed):
    rng = np.random.default_rng(seed)
    return (rng.random((n, m)) < p).astype(np.float32)


def random_ternary(n, m, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(-1, 2, (n, m)).astype(np.float32)


def random_vec(n, seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=n).astype(np.float32)


class TestBinaryKernel:
    @pytest.mark.parametrize("n,m,k", [(32, 32, 2), (64, 48, 4), (128, 130, 8)])
    def test_matches_dense(self, n, m, k):
        B = random_binary(n, m, 0.5, seed=n + m + k)
        v = random_vec(n, seed=k)
        got = rsr_pallas.rsr_apply_binary(v, B, k)
        np.testing.assert_allclose(got, v @ B, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("k", [1, 2, 3, 5, 7])
    def test_all_k_widths(self, k):
        n = 64
        B = random_binary(n, n, 0.5, seed=k)
        v = random_vec(n, seed=100 + k)
        got = rsr_pallas.rsr_apply_binary(v, B, k)
        np.testing.assert_allclose(got, v @ B, rtol=1e-4, atol=1e-4)

    def test_ragged_columns_are_padded(self):
        # m = 30 not divisible by k = 4 → wrapper pads and slices.
        B = random_binary(48, 30, 0.5, seed=7)
        v = random_vec(48, seed=8)
        got = rsr_pallas.rsr_apply_binary(v, B, 4)
        assert got.shape == (30,)
        np.testing.assert_allclose(got, v @ B, rtol=1e-4, atol=1e-4)

    def test_zero_matrix(self):
        B = np.zeros((32, 16), dtype=np.float32)
        v = random_vec(32, seed=9)
        got = rsr_pallas.rsr_apply_binary(v, B, 4)
        np.testing.assert_array_equal(got, np.zeros(16, dtype=np.float32))

    def test_all_ones_matrix(self):
        B = np.ones((32, 16), dtype=np.float32)
        v = random_vec(32, seed=10)
        got = rsr_pallas.rsr_apply_binary(v, B, 4)
        np.testing.assert_allclose(got, np.full(16, v.sum()), rtol=1e-4)

    def test_row_tiling_path(self, monkeypatch):
        # Force the in-kernel row tiling to take multiple iterations.
        monkeypatch.setattr(rsr_pallas, "ROW_TILE", 16)
        B = random_binary(50, 24, 0.5, seed=11)
        v = random_vec(50, seed=12)
        got = rsr_pallas.rsr_apply_binary(v, B, 4)
        np.testing.assert_allclose(got, v @ B, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(8, 96),
        nb=st.integers(1, 6),
        k=st.integers(1, 6),
        density=st.floats(0.05, 0.95),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, nb, k, density, seed):
        m = nb * k
        B = random_binary(n, m, density, seed)
        v = random_vec(n, seed ^ 0xABCDEF)
        got = rsr_pallas.rsr_apply_binary(v, B, k)
        np.testing.assert_allclose(got, v @ B, rtol=1e-3, atol=1e-3)


class TestTernaryKernel:
    @pytest.mark.parametrize("n,m,k", [(32, 32, 4), (96, 64, 5)])
    def test_matches_dense(self, n, m, k):
        A = random_ternary(n, m, seed=n * m)
        v = random_vec(n, seed=m)
        got = rsr_pallas.rsr_apply_ternary(v, A, k)
        np.testing.assert_allclose(got, v @ A, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(8, 64),
        nb=st.integers(1, 4),
        k=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n, nb, k, seed):
        m = nb * k
        A = random_ternary(n, m, seed)
        v = random_vec(n, seed ^ 0x13579B)
        got = rsr_pallas.rsr_apply_ternary(v, A, k)
        np.testing.assert_allclose(got, v @ A, rtol=1e-3, atol=1e-3)


class TestKernelVsRefPipeline:
    """The kernel must agree with the *reference RSR pipeline*, not just
    the dense product — catches compensating bugs."""

    @pytest.mark.parametrize("n,k", [(40, 4), (64, 6)])
    def test_kernel_equals_ref_rsr(self, n, k):
        B = random_binary(n, n - (n % k), 0.5, seed=n)
        v = random_vec(n, seed=k)
        kernel_out = rsr_pallas.rsr_apply_binary(v, B, k)
        ref_out = ref.rsr_matvec_ref(v, B, k)
        np.testing.assert_allclose(kernel_out, ref_out, rtol=1e-4, atol=1e-4)


class TestVmemModel:
    def test_footprint_grows_with_k(self):
        assert rsr_pallas.vmem_bytes(4096, 10) > rsr_pallas.vmem_bytes(4096, 4)

    def test_default_tile_fits_tpu_vmem(self):
        # The §Perf claim: k=8, any n → ≤ ~4MB per grid step.
        assert rsr_pallas.vmem_bytes(65536, 8) < 4 * 2**20

    def test_mxu_utilization_model(self):
        assert rsr_pallas.mxu_utilization_estimate(1024, 8) == pytest.approx(1 / 256)
